//! DSE-plane integration tests: seeded determinism (bit-reproducible
//! searches), Pareto-frontier invariants on real evaluations, the §V-B
//! 3-point regression (HALO1 ranks above both extremes), and the SLO
//! auto-tune mode picking a chunked-prefill config where the serialized
//! default misses the target.

use halo::cluster::{Mix, Policy};
use halo::dse::{
    dominates, explore, DseConfig, DseResult, Exhaustive, Fidelity, Objective, RandomSearch,
    SearchSpace, SloSpec,
};
use halo::model::LlmConfig;

fn cfg_with(requests: usize, seed: u64) -> DseConfig {
    let mut cfg = DseConfig::new(LlmConfig::llama2_7b(), Mix::Interactive);
    cfg.requests = requests;
    cfg.seed = seed;
    cfg
}

/// Bit-exact fingerprint of a result: every metric of every evaluated
/// candidate, in visit order, plus the frontier indices.
fn fingerprint(res: &DseResult) -> Vec<u64> {
    let mut out = Vec::new();
    for e in &res.evaluated {
        for s in &e.scores {
            out.push(s.to_bits());
        }
        out.push(e.metrics.ttft_p50.to_bits());
        out.push(e.metrics.e2e_p99.to_bits());
        out.push(e.metrics.throughput_rps.to_bits());
        out.push(e.metrics.cost.to_bits());
    }
    out.extend(res.frontier.iter().map(|&i| i as u64));
    out
}

#[test]
fn seeded_search_is_bit_reproducible() {
    let space = SearchSpace::smoke();
    let mut cfg = cfg_with(40, 11);
    cfg.rate = Some(12.0); // skip calibration: fixed offered load
    let a = explore(&space, &mut Exhaustive, &cfg);
    let b = explore(&space, &mut Exhaustive, &cfg);
    assert!(!a.evaluated.is_empty());
    assert_eq!(fingerprint(&a), fingerprint(&b), "grid search must be bit-reproducible");
    // stochastic strategies too: same seed, same everything
    let mut r1 = RandomSearch { samples: 6, seed: cfg.seed };
    let mut r2 = RandomSearch { samples: 6, seed: cfg.seed };
    let ra = explore(&space, &mut r1, &cfg);
    let rb = explore(&space, &mut r2, &cfg);
    assert_eq!(fingerprint(&ra), fingerprint(&rb), "random search must be bit-reproducible");
    assert!(ra.evaluated.len() <= 6);
}

#[test]
fn frontier_is_nonempty_nondominated_and_complete() {
    let space = SearchSpace::smoke();
    let mut cfg = cfg_with(48, 7);
    cfg.rate = Some(14.0);
    let res = explore(&space, &mut Exhaustive, &cfg);
    assert!(res.objectives.len() >= 3, "default objective set spans >= 3 dimensions");
    assert!(!res.frontier.is_empty(), "a finished search always has a frontier");
    for &i in &res.frontier {
        for e in &res.evaluated {
            assert!(
                !dominates(&e.scores, &res.evaluated[i].scores),
                "frontier point {i} is dominated"
            );
        }
    }
    // completeness: every dominated point is dominated by a frontier point
    for (i, e) in res.evaluated.iter().enumerate() {
        if res.frontier.contains(&i) {
            continue;
        }
        assert!(
            res.frontier
                .iter()
                .any(|&j| dominates(&res.evaluated[j].scores, &e.scores)),
            "non-frontier point {i} not dominated by any frontier point"
        );
    }
}

#[test]
fn vb_3point_search_ranks_halo1_above_both_extremes() {
    // the paper's §V-B argument as a degenerate search: on the paper
    // workload, phase-aware HALO1 must beat Fully-CiD (slow prefill) and
    // Fully-CiM (catastrophic decode) on median end-to-end latency
    let mut cfg = cfg_with(48, 17);
    cfg.objectives = vec![Objective::E2eP50, Objective::TtftP50, Objective::Throughput];
    let res = explore(&SearchSpace::mapping_extremes(), &mut Exhaustive, &cfg);
    assert_eq!(res.evaluated.len(), 3);
    let by_name = |name: &str| {
        res.evaluated
            .iter()
            .position(|e| e.candidate.composition.name() == name)
            .unwrap_or_else(|| panic!("{name} missing from the 3-point search"))
    };
    let halo = by_name("HALO1");
    let cid = by_name("Fully-CiD");
    let cim = by_name("Fully-CiM");
    let e2e = |i: usize| res.evaluated[i].metrics.e2e_p50;
    assert!(e2e(halo) < e2e(cid), "HALO1 {} vs Fully-CiD {}", e2e(halo), e2e(cid));
    assert!(e2e(halo) < e2e(cim), "HALO1 {} vs Fully-CiM {}", e2e(halo), e2e(cim));
    assert!(res.frontier.contains(&halo), "HALO1 must sit on the frontier");
    assert_eq!(res.best_by(Objective::E2eP50), Some(halo));
}

#[test]
fn slo_autotune_selects_chunked_prefill_where_serialized_misses() {
    // mild overload on one device: serialized FIFO head-of-line blocking
    // inflates median TTFT; chunked prefill streams long prompts through.
    // Pick the SLO between the two measured medians so only chunked
    // configs can meet it, then check the auto-tuner finds one.
    let space = SearchSpace::paper_point()
        .with_policies(vec![Policy::LeastLoaded])
        .with_devices(vec![1])
        .with_chunks(vec![0, 256, 512, 1024]);
    let mut cfg = cfg_with(160, 41);
    cfg.rate_scale = 1.25;
    let probe = explore(&space, &mut Exhaustive, &cfg);
    assert_eq!(probe.evaluated.len(), 4);
    let serialized = probe
        .evaluated
        .iter()
        .find(|e| e.candidate.chunk == 0)
        .expect("serialized point")
        .metrics
        .slo_ttft;
    let best_chunked = probe
        .evaluated
        .iter()
        .filter(|e| e.candidate.chunk > 0)
        .map(|e| e.metrics.slo_ttft)
        .fold(f64::INFINITY, f64::min);
    assert!(
        best_chunked < serialized,
        "chunked prefill must improve median TTFT: {best_chunked} vs {serialized}"
    );

    cfg.slo = Some(SloSpec::median((best_chunked + serialized) / 2.0));
    let tuned = explore(&space, &mut Exhaustive, &cfg);
    let pick = tuned.slo_choice.expect("some config must meet the SLO");
    let picked = &tuned.evaluated[pick];
    assert!(picked.candidate.chunk > 0, "the SLO pick must be a chunked config");
    assert!(picked.metrics.slo_ttft <= cfg.slo.unwrap().ttft);
    // and the serialized default indeed misses the target
    let serialized_tuned = tuned
        .evaluated
        .iter()
        .find(|e| e.candidate.chunk == 0)
        .expect("serialized point");
    assert!(serialized_tuned.metrics.slo_ttft > cfg.slo.unwrap().ttft);
    // all candidates cost the same here, so attainment drove the choice
    assert_eq!(picked.metrics.cost, serialized_tuned.metrics.cost);
}

#[test]
fn four_threads_fingerprint_bit_identically_to_one() {
    // the parallel worker pool is purely a wall-clock knob: the whole
    // result — metrics, scores, frontier, SLO choice, work counters —
    // must be bit-identical at any --threads N, for the grid and for a
    // seeded stochastic strategy alike
    let space = SearchSpace::paper_point()
        .with_policies(vec![Policy::LeastLoaded])
        .with_devices(vec![1, 2])
        .with_chunks(vec![0, 256, 512]);
    let mut cfg = cfg_with(32, 19);
    cfg.rate = Some(10.0);
    cfg.slo = Some(SloSpec::median(10.0));
    let t1 = explore(&space, &mut Exhaustive, &cfg);
    cfg.threads = 4;
    let t4 = explore(&space, &mut Exhaustive, &cfg);
    assert_eq!(fingerprint(&t1), fingerprint(&t4), "grid: threads must not change results");
    assert_eq!(t1.slo_choice, t4.slo_choice);
    for key in ["candidate_evals", "dse_memo_hits", "invalid_candidates", "graph_walks"] {
        assert_eq!(t1.profile.count(key), t4.profile.count(key), "{key}");
    }

    let big = SearchSpace::preset("power").expect("power preset");
    let mut cfg = cfg_with(24, 5);
    cfg.rate = Some(12.0);
    let mut r1 = RandomSearch { samples: 8, seed: cfg.seed };
    let a = explore(&big, &mut r1, &cfg);
    cfg.threads = 4;
    let mut r4 = RandomSearch { samples: 8, seed: cfg.seed };
    let b = explore(&big, &mut r4, &cfg);
    assert_eq!(fingerprint(&a), fingerprint(&b), "random: threads must not change results");
}

#[test]
fn halving_matches_the_exhaustive_slo_choice_with_fewer_full_replays() {
    // four fleet sizes, four distinct costs: the SLO auto-tune answer is
    // the cheapest config meeting the target. Successive halving must
    // reach the same pick while replaying the full trace for strictly
    // fewer candidates (here: only the survivor).
    let space = SearchSpace::paper_point()
        .with_policies(vec![Policy::LeastLoaded])
        .with_devices(vec![1, 2, 3, 4]);
    let mut cfg = cfg_with(96, 29);
    cfg.rate = Some(12.0);

    // probe without an SLO to calibrate one every candidate meets at any
    // trace prefix (TTFT never grows when the trace shrinks under a
    // fixed rate, so 4x the worst full-trace median is safely generous)
    let probe = explore(&space, &mut Exhaustive, &cfg);
    assert_eq!(probe.evaluated.len(), 4);
    let worst = probe.evaluated.iter().map(|e| e.metrics.slo_ttft).fold(0.0_f64, f64::max);
    assert!(worst.is_finite() && worst > 0.0);
    cfg.slo = Some(SloSpec::median(4.0 * worst));

    let ex = explore(&space, &mut Exhaustive, &cfg);
    let ex_pick = ex.slo_choice.expect("a generous SLO is always met");

    cfg.fidelity = Fidelity::halving();
    let sh = explore(&space, &mut Exhaustive, &cfg);
    let sh_pick = sh.slo_choice.expect("halving must still surface an SLO pick");
    assert_eq!(
        sh.evaluated[sh_pick].candidate.label(),
        ex.evaluated[ex_pick].candidate.label(),
        "halving must reach the exhaustive SLO choice"
    );

    // >= 3x fewer full-fidelity replays, and nothing silently dropped
    let (full_sh, full_ex) =
        (sh.profile.count("candidate_evals"), ex.profile.count("candidate_evals"));
    assert!(
        full_sh * 3 <= full_ex,
        "halving must cut full replays >= 3x: {full_sh} vs {full_ex}"
    );
    assert_eq!(
        sh.evaluated.len() as u64 + sh.profile.count("sh_pruned"),
        sh.profile.count("sh_pool"),
        "pool = survivors + pruned"
    );
}

#[test]
fn multi_tenant_objective_feeds_the_search() {
    let space = SearchSpace::paper_point().with_chunks(vec![0, 512]);
    let mut cfg = cfg_with(60, 23);
    cfg.rate = Some(20.0);
    cfg.tenants = 3;
    cfg.objectives =
        vec![Objective::WorstTenantTtft, Objective::Throughput, Objective::Cost];
    let res = explore(&space, &mut Exhaustive, &cfg);
    assert_eq!(res.evaluated.len(), 2);
    for e in &res.evaluated {
        assert!(e.metrics.worst_tenant_ttft_p99 > 0.0);
        assert_eq!(e.scores.len(), 3);
    }
    // with a single tenant the fairness metric degenerates to the global
    // TTFT p99 exactly (same served set, same percentile)
    cfg.tenants = 1;
    let single = explore(&space, &mut Exhaustive, &cfg);
    for e in &single.evaluated {
        assert_eq!(
            e.metrics.worst_tenant_ttft_p99.to_bits(),
            e.metrics.ttft_p99.to_bits()
        );
    }
}

//! Causal critical-path extraction: *what resource* binds each request.
//!
//! `obs::attrib` answers "where did the time go" per pipeline component;
//! this module answers the next question — what hardware resource was
//! the binding constraint along each served request's dependency chain,
//! and therefore what a hardware change would actually buy. From the
//! recorded span timelines it reconstructs each request's critical path
//! across devices:
//!
//! queue wait → prefill chunks (with admission-gate edges) → KV handoff
//! over the interconnect → decode steps (batch-coupled to co-resident
//! requests) → throttle stalls and eviction recompute
//!
//! and classifies every segment by binding resource ([`Resource`]):
//! CiM compute binds prefill, CiD/HBM bandwidth binds decode, the
//! interposer binds KV handoff, KV capacity binds recompute and
//! admission-blocked queueing, the scheduler binds gaps between busy
//! intervals, and the thermal governor binds throttle stalls — HALO's
//! phase-flipping bottleneck argument, made measurable per request.
//!
//! **Bit-exact discipline** (same as `obs::attrib`): each path ends in a
//! signed `closure` segment computed with the shared ulp-correcting
//! residual, so folding every segment duration from 0.0 reproduces the
//! recorded e2e to the last bit — pinned by [`reconcile_paths`] and
//! enforced in CI. Under retention-cap span drops extraction degrades
//! gracefully: inferred segments fall to [`Resource::Unattributed`] and
//! each path reports the [`CritPath::coverage`] fraction its recorded
//! service spans actually evidence.

use std::collections::{HashMap, HashSet};

use super::attrib::residual;
use super::span::{EventKind, Recorder, Span, SpanKind};
use crate::sim::queueing::ServedRequest;

/// The binding resource of a critical-path segment.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Resource {
    /// CiM tile compute — prefill / chunked prefill passes.
    CimCompute,
    /// CiD/HBM bandwidth — batched decode steps.
    CidBandwidth,
    /// Interposer / interconnect — KV-cache handoff transfers.
    Interconnect,
    /// KV byte budget — eviction recompute and admission-blocked waits.
    KvCapacity,
    /// Queue / scheduler — waits between busy intervals.
    Scheduler,
    /// Thermal governor — throttle stall carved out of service spans.
    Thermal,
    /// Closure under lossy observation (retention-cap drops).
    Unattributed,
}

pub const N_RESOURCES: usize = 7;

impl Resource {
    pub const ALL: [Resource; N_RESOURCES] = [
        Resource::CimCompute,
        Resource::CidBandwidth,
        Resource::Interconnect,
        Resource::KvCapacity,
        Resource::Scheduler,
        Resource::Thermal,
        Resource::Unattributed,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            Resource::CimCompute => "cim_compute",
            Resource::CidBandwidth => "cid_bandwidth",
            Resource::Interconnect => "interconnect",
            Resource::KvCapacity => "kv_capacity",
            Resource::Scheduler => "scheduler",
            Resource::Thermal => "thermal",
            Resource::Unattributed => "unattributed",
        }
    }

    pub fn index(&self) -> usize {
        Resource::ALL.iter().position(|r| r == self).unwrap()
    }
}

/// One segment of a request's critical path, in simulated seconds.
#[derive(Debug, Clone, Copy)]
pub struct Segment {
    /// What the segment was: `queue_wait`, `prefill`, `prefill_chunk`,
    /// `recompute`, `kv_handoff`, `decode_step`, `throttle_stall`,
    /// `gap`, or the final signed `closure`.
    pub label: &'static str,
    pub resource: Resource,
    /// Serving phase this segment belongs to (`prefill` before the
    /// first token, `decode` after).
    pub phase: &'static str,
    pub start: f64,
    /// Signed duration; only the final `closure` segment may be
    /// negative (it is the ulp-correcting residual).
    pub dur: f64,
}

/// One served request's extracted critical path.
#[derive(Debug, Clone)]
pub struct CritPath {
    pub arrival: f64,
    /// Recorded TTFT — the phase boundary for segment classification.
    pub ttft: f64,
    /// Recorded e2e — bit-exactly the fold of the segment durations.
    pub e2e: f64,
    pub segments: Vec<Segment>,
    /// Fraction of e2e directly evidenced by recorded service spans
    /// (prefill/recompute/handoff/decode/stall), in `[0, 1]`. Queue
    /// wait and scheduler gaps are inferred, not evidenced, so a
    /// heavily queued request reports < 1 even under full observation;
    /// retention-cap drops push it further down.
    pub coverage: f64,
}

impl CritPath {
    /// Left fold of the segment durations from 0.0 — reproduces
    /// [`Self::e2e`] bit-exactly (pinned by [`reconcile_paths`]).
    pub fn fold(&self) -> f64 {
        self.segments.iter().fold(0.0, |acc, s| acc + s.dur)
    }

    /// Total critical-path seconds per resource, in [`Resource::ALL`]
    /// order.
    pub fn per_resource(&self) -> [f64; N_RESOURCES] {
        let mut t = [0.0; N_RESOURCES];
        for s in &self.segments {
            t[s.resource.index()] += s.dur;
        }
        t
    }
}

/// Labels whose segments count as directly recorded service evidence.
fn is_service(label: &str) -> bool {
    matches!(
        label,
        "prefill" | "prefill_chunk" | "recompute" | "kv_handoff" | "decode_step" | "throttle_stall"
    )
}

/// A raw busy interval joined to one request, before the path walk.
#[derive(Clone, Copy)]
struct Interval {
    start: f64,
    dur: f64,
    label: &'static str,
    resource: Resource,
}

/// Extract every served request's critical path from the fleet's
/// recorded span timelines (`recorders`, device order), decode-batch
/// membership records, and the interconnect's KV-transfer spans.
/// Requests join to spans by exact arrival time (unique per stream by
/// construction). Never panics on lossy input: dropped observation
/// shows up as `Unattributed` closure and reduced coverage.
pub fn extract_paths(
    served: &[ServedRequest],
    recorders: &[&Recorder],
    kv_spans: &[Span],
) -> Vec<CritPath> {
    let idx: HashMap<u64, usize> =
        served.iter().enumerate().map(|(i, r)| (r.arrival.to_bits(), i)).collect();
    let n = served.len();
    let mut intervals: Vec<Vec<Interval>> = vec![Vec::new(); n];
    let mut stall = vec![0.0f64; n];
    let mut blocked: HashSet<u64> = HashSet::new();
    let lossy = recorders.iter().any(|r| r.dropped() != (0, 0) || r.dropped_batches() > 0);
    for rec in recorders {
        for s in &rec.spans {
            let Some(&i) = idx.get(&s.arrival.to_bits()) else { continue };
            let (label, resource) = match s.kind {
                SpanKind::Prefill => ("prefill", Resource::CimCompute),
                SpanKind::PrefillChunk => ("prefill_chunk", Resource::CimCompute),
                SpanKind::Recompute => ("recompute", Resource::KvCapacity),
                SpanKind::KvTransfer => ("kv_handoff", Resource::Interconnect),
                // decode steps carry arrival -1.0; membership arrives
                // via the batch side-channel below
                SpanKind::DecodeStep => continue,
            };
            intervals[i].push(Interval { start: s.start, dur: s.dur, label, resource });
        }
        for b in &rec.batches {
            for a in &b.arrivals {
                if let Some(&i) = idx.get(&a.to_bits()) {
                    intervals[i].push(Interval {
                        start: b.start,
                        dur: b.dur,
                        label: "decode_step",
                        resource: Resource::CidBandwidth,
                    });
                }
            }
        }
        for e in &rec.events {
            match e.kind {
                EventKind::Throttle => {
                    if let Some(&i) = idx.get(&e.arrival.to_bits()) {
                        stall[i] += e.stall_s;
                    }
                }
                EventKind::AdmitBlocked => {
                    blocked.insert(e.arrival.to_bits());
                }
                _ => {}
            }
        }
    }
    for s in kv_spans {
        if s.kind == SpanKind::KvTransfer {
            if let Some(&i) = idx.get(&s.arrival.to_bits()) {
                intervals[i].push(Interval {
                    start: s.start,
                    dur: s.dur,
                    label: "kv_handoff",
                    resource: Resource::Interconnect,
                });
            }
        }
    }
    served
        .iter()
        .enumerate()
        .map(|(i, r)| {
            build_path(r, &mut intervals[i], stall[i], blocked.contains(&r.arrival.to_bits()), lossy)
        })
        .collect()
}

/// Walk one request's sorted busy intervals from its arrival, emitting
/// gap segments for waits, verbatim segments for recorded service,
/// carving the thermal stall out, and closing with the bit-exact
/// residual.
fn build_path(
    r: &ServedRequest,
    intervals: &mut [Interval],
    stall_s: f64,
    kv_blocked: bool,
    lossy: bool,
) -> CritPath {
    intervals.sort_by(|a, b| {
        a.start.partial_cmp(&b.start).unwrap().then(a.dur.partial_cmp(&b.dur).unwrap())
    });
    let t_first = r.arrival + r.ttft;
    let phase_of = |start: f64| if start < t_first { "prefill" } else { "decode" };
    let infer_resource = |wait: Resource| if lossy { Resource::Unattributed } else { wait };
    let mut segments: Vec<Segment> = Vec::new();
    let mut cursor = r.arrival;
    let mut first_gap = true;
    for iv in intervals.iter() {
        if iv.dur <= 0.0 {
            continue;
        }
        if iv.start > cursor {
            let (label, res) = if first_gap {
                // the head-of-path wait is queue wait; an admission-gate
                // event reclassifies it as KV-capacity-bound
                let bound = if kv_blocked { Resource::KvCapacity } else { Resource::Scheduler };
                ("queue_wait", bound)
            } else {
                ("gap", Resource::Scheduler)
            };
            segments.push(Segment {
                label,
                resource: infer_resource(res),
                phase: phase_of(cursor),
                start: cursor,
                dur: iv.start - cursor,
            });
            cursor = iv.start;
        }
        first_gap = false;
        let end = iv.start + iv.dur;
        if end <= cursor {
            continue; // fully shadowed by an earlier interval
        }
        // trim any overlap with the path walked so far: the critical
        // path only takes the part past the cursor
        let start = cursor.max(iv.start);
        segments.push(Segment {
            label: iv.label,
            resource: iv.resource,
            phase: phase_of(start),
            start,
            dur: end - start,
        });
        cursor = end;
    }
    // carve the thermal governor's stall out of the service segments it
    // stretched (prefill first, excess out of recompute — the same
    // netting order as obs::attrib), surfacing it as its own segment
    if stall_s > 0.0 {
        let mut remaining = stall_s;
        let mut last_carved = None;
        for pass in 0..2 {
            for (k, s) in segments.iter_mut().enumerate() {
                if remaining <= 0.0 {
                    break;
                }
                let eligible = match pass {
                    0 => s.resource == Resource::CimCompute,
                    _ => s.label == "recompute",
                };
                if !eligible {
                    continue;
                }
                let take = remaining.min(s.dur.max(0.0));
                if take > 0.0 {
                    s.dur -= take;
                    remaining -= take;
                    last_carved = Some(k);
                }
            }
        }
        let carved = stall_s - remaining;
        if carved > 0.0 {
            let at = last_carved.unwrap();
            let seg = segments[at];
            segments.insert(
                at + 1,
                Segment {
                    label: "throttle_stall",
                    resource: Resource::Thermal,
                    phase: seg.phase,
                    start: seg.start + seg.dur,
                    dur: carved,
                },
            );
        }
    }
    // bit-exact closure: whatever the walk could not evidence (decode
    // inter-cycle waits under full observation; dropped spans under a
    // retention cap) lands in the signed residual
    let parts: Vec<f64> = segments.iter().map(|s| s.dur).collect();
    let closure = residual(r.e2e, &parts);
    let has_decode = segments.iter().any(|s| s.resource == Resource::CidBandwidth);
    segments.push(Segment {
        label: "closure",
        resource: if lossy || !has_decode { Resource::Unattributed } else { Resource::Scheduler },
        phase: "decode",
        start: cursor,
        dur: closure,
    });
    let service: f64 =
        segments.iter().filter(|s| is_service(s.label)).map(|s| s.dur.max(0.0)).sum();
    let coverage = if r.e2e > 0.0 { (service / r.e2e).clamp(0.0, 1.0) } else { 1.0 };
    CritPath { arrival: r.arrival, ttft: r.ttft, e2e: r.e2e, segments, coverage }
}

/// Number of paths whose segment fold does *not* reproduce the recorded
/// e2e bit-exactly. Must be 0; CI fails otherwise.
pub fn reconcile_paths(paths: &[CritPath]) -> usize {
    paths.iter().filter(|p| p.fold().to_bits() != p.e2e.to_bits()).count()
}

/// One row of the fleet bottleneck profile.
#[derive(Debug, Clone, Copy)]
pub struct BottleneckRow {
    pub resource: Resource,
    /// Critical-path seconds bound by this resource, whole population.
    pub total_s: f64,
    /// Share of all critical-path time.
    pub share: f64,
    /// Critical-path seconds over the p-tail (slowest requests by e2e).
    pub tail_s: f64,
    /// Share of the tail's critical-path time.
    pub tail_share: f64,
}

/// Aggregate paths into a per-resource bottleneck profile, population
/// vs the e2e tail at percentile `p` (e.g. 99.0 → slowest 1%). Always
/// returns one row per [`Resource::ALL`] entry (stable table shape);
/// empty input yields an empty vec.
pub fn bottleneck_profile(paths: &[CritPath], p: f64) -> Vec<BottleneckRow> {
    if paths.is_empty() {
        return Vec::new();
    }
    let mut order: Vec<usize> = (0..paths.len()).collect();
    order.sort_by(|&a, &b| paths[a].e2e.partial_cmp(&paths[b].e2e).unwrap());
    let cut = ((p.clamp(0.0, 100.0) / 100.0) * paths.len() as f64) as usize;
    let tail = &order[cut.min(paths.len() - 1)..];
    let mut total = [0.0f64; N_RESOURCES];
    let mut tail_t = [0.0f64; N_RESOURCES];
    for p in paths {
        for (t, v) in total.iter_mut().zip(p.per_resource()) {
            *t += v;
        }
    }
    for &i in tail {
        for (t, v) in tail_t.iter_mut().zip(paths[i].per_resource()) {
            *t += v;
        }
    }
    let grand: f64 = total.iter().sum::<f64>().max(1e-12);
    let tail_grand: f64 = tail_t.iter().sum::<f64>().max(1e-12);
    Resource::ALL
        .iter()
        .map(|&resource| {
            let k = resource.index();
            BottleneckRow {
                resource,
                total_s: total[k],
                share: total[k] / grand,
                tail_s: tail_t[k],
                tail_share: tail_t[k] / tail_grand,
            }
        })
        .collect()
}

/// One row of the per-phase bottleneck profile.
#[derive(Debug, Clone, Copy)]
pub struct PhaseRow {
    pub phase: &'static str,
    pub resource: Resource,
    pub total_s: f64,
    /// Share of this phase's critical-path time.
    pub share: f64,
}

/// Per-phase resource profile: which resource binds prefill vs decode —
/// the paper's phase-flip, read off the extracted paths. Rows are
/// emitted phase-major in [`Resource::ALL`] order.
pub fn phase_profile(paths: &[CritPath]) -> Vec<PhaseRow> {
    let mut totals = [[0.0f64; N_RESOURCES]; 2];
    for p in paths {
        for s in &p.segments {
            let ph = usize::from(s.phase == "decode");
            totals[ph][s.resource.index()] += s.dur;
        }
    }
    let mut rows = Vec::with_capacity(2 * N_RESOURCES);
    for (ph, name) in [(0usize, "prefill"), (1usize, "decode")] {
        let grand: f64 = totals[ph].iter().sum::<f64>().max(1e-12);
        for &resource in &Resource::ALL {
            let t = totals[ph][resource.index()];
            rows.push(PhaseRow { phase: name, resource, total_s: t, share: t / grand });
        }
    }
    rows
}

/// Per-window resource totals over simulated time.
#[derive(Debug, Clone, Copy)]
pub struct WindowProfile {
    pub start_s: f64,
    /// Seconds per resource ([`Resource::ALL`] order) from paths
    /// completing in this window.
    pub totals: [f64; N_RESOURCES],
    pub completions: u64,
}

/// Bucket each path's critical-path time into fixed windows by its
/// completion time (`arrival + e2e`) — aligned with the monitor plane's
/// `WindowSeries` when called with its `width_s()`/`len()`. Paths
/// completing past the last window fold into it (same clamp the window
/// series applies).
pub fn windowed_profile(paths: &[CritPath], width_s: f64, n_windows: usize) -> Vec<WindowProfile> {
    if width_s <= 0.0 || n_windows == 0 {
        return Vec::new();
    }
    let mut out: Vec<WindowProfile> = (0..n_windows)
        .map(|i| WindowProfile {
            start_s: i as f64 * width_s,
            totals: [0.0; N_RESOURCES],
            completions: 0,
        })
        .collect();
    for p in paths {
        let t = p.arrival + p.e2e;
        let i = ((t / width_s) as usize).min(n_windows - 1);
        for (acc, v) in out[i].totals.iter_mut().zip(p.per_resource()) {
            *acc += v;
        }
        out[i].completions += 1;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(arrival: f64, ttft: f64, e2e: f64) -> ServedRequest {
        ServedRequest { arrival, ttft, e2e, tenant: 0, session: 0, tokens: 4 }
    }

    fn span(kind: SpanKind, start: f64, dur: f64, arrival: f64) -> Span {
        Span { kind, start, dur, arrival, batch: 1 }
    }

    #[test]
    fn handcrafted_path_reconstructs_queue_prefill_handoff_decode() {
        // arrival 0.0, queue 0.2, prefill [0.2,0.7), handoff [0.7,0.8),
        // decode steps [0.9,1.0) and [1.1,1.2); e2e ends at 1.2
        let served = vec![req(0.0, 0.7, 1.2)];
        let mut rec = Recorder::new();
        rec.spans.push(span(SpanKind::Prefill, 0.2, 0.5, 0.0));
        rec.decode_batch(0.9, 0.1, vec![0.0]);
        rec.decode_batch(1.1, 0.1, vec![0.0, 5.0]);
        let kv = vec![span(SpanKind::KvTransfer, 0.7, 0.1, 0.0)];
        let paths = extract_paths(&served, &[&rec], &kv);
        assert_eq!(paths.len(), 1);
        assert_eq!(reconcile_paths(&paths), 0);
        let p = &paths[0];
        let labels: Vec<_> = p.segments.iter().map(|s| s.label).collect();
        assert_eq!(
            labels,
            vec![
                "queue_wait",
                "prefill",
                "kv_handoff",
                "gap",
                "decode_step",
                "gap",
                "decode_step",
                "closure"
            ]
        );
        assert_eq!(p.segments[0].resource, Resource::Scheduler);
        assert_eq!(p.segments[1].resource, Resource::CimCompute);
        assert_eq!(p.segments[1].phase, "prefill");
        assert_eq!(p.segments[2].resource, Resource::Interconnect);
        assert_eq!(p.segments[2].phase, "decode");
        assert_eq!(p.segments[4].resource, Resource::CidBandwidth);
        // closure is tiny under full observation here (gaps are walked)
        assert!(p.segments.last().unwrap().dur.abs() < 1e-9);
        assert!(p.coverage > 0.5 && p.coverage <= 1.0);
    }

    #[test]
    fn admission_blocked_queue_wait_is_kv_capacity_bound() {
        let served = vec![req(1.0, 1.5, 2.0)];
        let mut rec = Recorder::new();
        rec.spans.push(span(SpanKind::Prefill, 2.0, 0.5, 1.0));
        rec.decode_batch(2.5, 0.2, vec![1.0]);
        rec.event(EventKind::AdmitBlocked, 1.3, 1.0);
        let paths = extract_paths(&served, &[&rec], &[]);
        let p = &paths[0];
        assert_eq!(p.segments[0].label, "queue_wait");
        assert_eq!(p.segments[0].resource, Resource::KvCapacity);
        assert_eq!(reconcile_paths(&paths), 0);
    }

    #[test]
    fn throttle_stall_is_carved_into_a_thermal_segment() {
        let served = vec![req(0.0, 0.6, 1.0)];
        let mut rec = Recorder::new();
        // busy_span with growing throttled_s emits the Throttle event
        rec.busy_span(span(SpanKind::Prefill, 0.0, 0.6, 0.0), 0.1, 1);
        rec.decode_batch(0.6, 0.4, vec![0.0]);
        let paths = extract_paths(&served, &[&rec], &[]);
        let p = &paths[0];
        let th: Vec<_> = p.segments.iter().filter(|s| s.resource == Resource::Thermal).collect();
        assert_eq!(th.len(), 1);
        assert_eq!(th[0].label, "throttle_stall");
        assert!((th[0].dur - 0.1).abs() < 1e-12);
        // the prefill segment shrank by the carved stall
        let pf = p.segments.iter().find(|s| s.label == "prefill").unwrap();
        assert!((pf.dur - 0.5).abs() < 1e-12);
        assert_eq!(th[0].phase, "prefill");
        assert_eq!(reconcile_paths(&paths), 0);
    }

    #[test]
    fn no_observation_at_all_still_folds_bit_exactly() {
        // nothing joined: the whole e2e is one queue wait plus closure
        let served = vec![req(3.0, 0.4, 2.7)];
        let paths = extract_paths(&served, &[&Recorder::new()], &[]);
        assert_eq!(reconcile_paths(&paths), 0);
        let p = &paths[0];
        assert_eq!(p.coverage, 0.0);
        // no decode evidence => closure is unattributed, not scheduler
        assert_eq!(p.segments.last().unwrap().resource, Resource::Unattributed);
    }

    #[test]
    fn lossy_recorders_degrade_to_unattributed_without_panicking() {
        let served = vec![req(0.0, 0.5, 1.0), req(0.1, 0.6, 1.1)];
        let mut rec = Recorder::with_cap(1);
        rec.busy_span(span(SpanKind::Prefill, 0.2, 0.3, 0.0), 0.0, 0);
        rec.busy_span(span(SpanKind::Prefill, 0.5, 0.2, 0.1), 0.0, 0); // dropped
        let paths = extract_paths(&served, &[&rec], &[]);
        assert_eq!(reconcile_paths(&paths), 0, "lossy paths still fold bit-exactly");
        // inferred waits are unattributed under drops
        assert!(paths[0]
            .segments
            .iter()
            .filter(|s| !is_service(s.label))
            .all(|s| s.resource == Resource::Unattributed));
        // the request whose span was dropped has zero coverage
        assert_eq!(paths[1].coverage, 0.0);
        assert!(paths[0].coverage > 0.0);
    }

    #[test]
    fn overlapping_intervals_are_trimmed_not_double_counted() {
        let served = vec![req(0.0, 0.5, 1.0)];
        let mut rec = Recorder::new();
        rec.spans.push(span(SpanKind::PrefillChunk, 0.0, 0.4, 0.0));
        rec.spans.push(span(SpanKind::PrefillChunk, 0.2, 0.3, 0.0)); // overlaps 0.2..0.4
        rec.decode_batch(0.5, 0.5, vec![0.0]);
        let paths = extract_paths(&served, &[&rec], &[]);
        assert_eq!(reconcile_paths(&paths), 0);
        let p = &paths[0];
        let chunk_total: f64 =
            p.segments.iter().filter(|s| s.label == "prefill_chunk").map(|s| s.dur).sum();
        assert!((chunk_total - 0.5).abs() < 1e-12, "0.0..0.5 walked once, got {chunk_total}");
    }

    #[test]
    fn bottleneck_profile_shares_sum_to_one_and_shape_is_stable() {
        let served: Vec<ServedRequest> =
            (0..50).map(|k| req(k as f64, 0.2, 0.5 + (k % 7) as f64 * 0.3)).collect();
        let mut rec = Recorder::new();
        for r in &served {
            rec.spans.push(span(SpanKind::Prefill, r.arrival + 0.05, 0.15, r.arrival));
            rec.decode_batch(r.arrival + 0.2, 0.1, vec![r.arrival]);
        }
        let paths = extract_paths(&served, &[&rec], &[]);
        assert_eq!(reconcile_paths(&paths), 0);
        let rows = bottleneck_profile(&paths, 90.0);
        assert_eq!(rows.len(), N_RESOURCES);
        let share: f64 = rows.iter().map(|r| r.share).sum();
        let tail_share: f64 = rows.iter().map(|r| r.tail_share).sum();
        assert!((share - 1.0).abs() < 1e-9);
        assert!((tail_share - 1.0).abs() < 1e-9);
        assert!(bottleneck_profile(&[], 99.0).is_empty());
    }

    #[test]
    fn phase_profile_separates_prefill_and_decode_resources() {
        let served = vec![req(0.0, 0.5, 1.5)];
        let mut rec = Recorder::new();
        rec.spans.push(span(SpanKind::Prefill, 0.1, 0.4, 0.0));
        rec.decode_batch(0.5, 1.0, vec![0.0]);
        let paths = extract_paths(&served, &[&rec], &[]);
        let rows = phase_profile(&paths);
        assert_eq!(rows.len(), 2 * N_RESOURCES);
        let pick = |phase: &str, res: Resource| {
            rows.iter().find(|r| r.phase == phase && r.resource == res).unwrap().total_s
        };
        assert!(pick("prefill", Resource::CimCompute) > 0.0);
        assert_eq!(pick("prefill", Resource::CidBandwidth), 0.0);
        assert!(pick("decode", Resource::CidBandwidth) > 0.0);
        assert_eq!(pick("decode", Resource::CimCompute), 0.0);
    }

    #[test]
    fn windowed_profile_buckets_by_completion_and_clamps() {
        let paths = extract_paths(
            &[req(0.5, 0.1, 0.4), req(3.0, 0.1, 0.5), req(100.0, 0.1, 1.0)],
            &[&Recorder::new()],
            &[],
        );
        let w = windowed_profile(&paths, 2.0, 3);
        assert_eq!(w.len(), 3);
        assert_eq!(w[0].completions, 1, "completion at 0.9 lands in [0,2)");
        assert_eq!(w[1].completions, 1, "completion at 3.5 lands in [2,4)");
        assert_eq!(w[2].completions, 1, "past-horizon completion clamps into the last window");
        assert!(windowed_profile(&paths, 0.0, 4).is_empty());
    }
}

//! Design-space exploration and SLO auto-tuning over the whole simulator.
//!
//! The paper's methodology is a search: sweep the architectural extremes
//! (Fully-CiD, Fully-CiM, phase-aware; §V-B), score each point, and pick
//! the winner. This plane turns that from a hand-run argument into an
//! engine — "evaluate one point" becomes "find the best point":
//!
//! * [`space`] — the searchable cross product: router policy, fleet
//!   composition (uniform or heterogeneous HALO1/HALO2/SA), device count,
//!   pool split, scheduler knobs (chunk / admission / KV budget),
//!   hardware knobs (CiM tile mesh, interposer bandwidth), and the power
//!   knobs (per-package TDP cap, per-phase DVFS operating points);
//! * [`strategy`] — pluggable, seeded, deterministic search drivers:
//!   exhaustive grid, random sampling, steepest hill-climb with restarts;
//! * [`objective`] — multi-objective scoring (TTFT p50/p99, decode
//!   throughput, evictions, SLO attainment, fleet cost, and the power
//!   plane's energy-per-token / EDP / peak-power);
//! * [`pareto`] — dominance and frontier extraction.
//!
//! [`explore`] wires them together: it calibrates one offered load,
//! generates one trace, memoizes every candidate's replay (revisits are
//! free, so hill-climbs can wander), and returns every evaluated point,
//! the Pareto frontier, and — when a TTFT SLO is given — the *cheapest*
//! configuration that meets it. Everything is deterministic per seed:
//! two runs with the same arguments are bit-identical.

pub mod objective;
pub mod pareto;
pub mod space;
pub mod strategy;

use std::collections::BTreeMap;

pub use objective::{fleet_cost, Direction, Metrics, Objective};
pub use pareto::{dominates, pareto_indices};
pub use space::{Candidate, Composition, Index, SearchSpace, AXES};
pub use strategy::{Exhaustive, HillClimb, RandomSearch, Strategy};

use crate::cluster::{Interconnect, Mix};
use crate::config::HwConfig;
use crate::model::LlmConfig;
use crate::obs::SelfProfile;
use crate::report::cluster::single_device_capacity;
use crate::sim::queueing::TraceRequest;

/// A TTFT service-level objective: the TTFT at `pct` (a percentile in
/// 0..=100) must not exceed `ttft` seconds.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SloSpec {
    pub ttft: f64,
    pub pct: f64,
}

impl SloSpec {
    /// Median-TTFT SLO (the default percentile).
    pub fn median(ttft: f64) -> Self {
        SloSpec { ttft, pct: 50.0 }
    }
}

/// Everything one exploration run needs besides the space and strategy.
#[derive(Debug, Clone)]
pub struct DseConfig {
    pub llm: LlmConfig,
    pub mix: Mix,
    /// Requests per evaluated trace.
    pub requests: usize,
    /// Seeds both the trace and any stochastic strategy.
    pub seed: u64,
    /// Decode slots per device.
    pub slots: usize,
    pub link: Interconnect,
    /// Absolute offered load in req/s; `None` calibrates it as
    /// `rate_scale x` one paper-default device's saturated throughput.
    pub rate: Option<f64>,
    pub rate_scale: f64,
    /// Tenants in the trace (1 = untagged single-tenant).
    pub tenants: usize,
    pub slo: Option<SloSpec>,
    /// Scored dimensions; the first one doubles as the scalar guidance
    /// for hill-climbing when no SLO is set.
    pub objectives: Vec<Objective>,
    pub base_hw: HwConfig,
}

impl DseConfig {
    pub fn new(llm: LlmConfig, mix: Mix) -> Self {
        DseConfig {
            llm,
            mix,
            requests: 96,
            seed: 42,
            slots: 8,
            link: Interconnect::board(),
            rate: None,
            rate_scale: 1.5,
            tenants: 1,
            slo: None,
            objectives: Objective::default_set(),
            base_hw: HwConfig::paper(),
        }
    }
}

/// One evaluated point of the space.
#[derive(Debug, Clone)]
pub struct Evaluated {
    pub index: Index,
    pub candidate: Candidate,
    pub metrics: Metrics,
    /// Minimized coordinates, one per configured objective.
    pub scores: Vec<f64>,
}

/// The outcome of one exploration run.
#[derive(Debug, Clone)]
pub struct DseResult {
    pub objectives: Vec<Objective>,
    pub slo: Option<SloSpec>,
    /// The offered load every candidate was replayed under, req/s.
    pub rate: f64,
    /// Every distinct evaluated candidate, in first-visit order.
    pub evaluated: Vec<Evaluated>,
    /// Indices into `evaluated` of the Pareto-optimal points, sorted by
    /// the first objective.
    pub frontier: Vec<usize>,
    /// Index of the cheapest candidate meeting the SLO, if one was set
    /// and met.
    pub slo_choice: Option<usize>,
    /// Self-profiling of the exploration itself: wall time and counts
    /// per stage (candidate evals, memo hits, graph walks). Host
    /// measurement metadata — excluded from the determinism guarantee,
    /// which covers everything else in this struct.
    pub profile: SelfProfile,
}

impl DseResult {
    pub fn frontier_points(&self) -> Vec<&Evaluated> {
        self.frontier.iter().map(|&i| &self.evaluated[i]).collect()
    }

    /// Index of the evaluated candidate best on `obj` (by minimized
    /// score; ties resolve to the earliest-visited).
    pub fn best_by(&self, obj: Objective) -> Option<usize> {
        (0..self.evaluated.len())
            .min_by(|&a, &b| {
                obj.score(&self.evaluated[a].metrics)
                    .total_cmp(&obj.score(&self.evaluated[b].metrics))
            })
    }

    fn meets_slo(&self, i: usize) -> bool {
        match self.slo {
            None => false,
            Some(slo) => self.evaluated[i].metrics.slo_ttft <= slo.ttft,
        }
    }
}

/// Scalar guidance for strategies: the SLO-penalized cost in auto-tune
/// mode (any config missing the SLO scores worse than every config
/// meeting it), else the first objective.
fn scalarize(cfg: &DseConfig, m: &Metrics) -> f64 {
    match cfg.slo {
        Some(slo) => {
            if m.slo_ttft <= slo.ttft {
                m.cost
            } else {
                1e12 + (m.slo_ttft - slo.ttft)
            }
        }
        None => cfg.objectives[0].score(m),
    }
}

/// Replay one candidate; returns its metrics plus the replay's graph
/// walks and cost-oracle memo hits for the exploration's self-profile.
fn evaluate_candidate(
    cand: &Candidate,
    cfg: &DseConfig,
    trace: &[TraceRequest],
) -> (Metrics, u64, u64) {
    let hw = cand.hw(&cfg.base_hw);
    let (mut fleet, mut router) = cand.build_fleet(&cfg.llm, &hw, cfg.slots, cfg.link.clone());
    let r = fleet.replay(trace, router.as_mut());
    let m = Metrics::collect(cand, &r, cfg.slo.map(|s| (s.ttft, s.pct)));
    (m, fleet.cost_walks(), fleet.cost_memo_hits())
}

/// Run one exploration: calibrate the offered load, drive `strategy`
/// over `space` with memoized candidate evaluation, then extract the
/// Pareto frontier and the SLO choice. Deterministic per (space,
/// strategy, cfg) — including bit-identical floating-point results.
pub fn explore(
    space: &SearchSpace,
    strategy: &mut dyn Strategy,
    cfg: &DseConfig,
) -> DseResult {
    assert!(!cfg.objectives.is_empty(), "need at least one objective");
    assert!(cfg.requests > 0 && cfg.slots > 0 && cfg.tenants > 0);
    let mut prof = SelfProfile::new();
    let rate = prof.time("calibrate_rate", || {
        cfg.rate.unwrap_or_else(|| {
            cfg.rate_scale * single_device_capacity(&cfg.base_hw, &cfg.llm, cfg.mix, cfg.slots)
        })
    });
    let trace =
        prof.time("trace_gen", || cfg.mix.trace_tenants(cfg.seed, cfg.requests, rate, cfg.tenants));

    let mut evaluated: Vec<Evaluated> = Vec::new();
    // memo keyed on the canonical index (axes a topology ignores are
    // pinned), so physically identical points replay once and appear as
    // one frontier row; invalid points pin to +inf
    let mut memo: BTreeMap<Index, f64> = BTreeMap::new();
    {
        let mut eval = |idx: &Index| -> f64 {
            let key = space.canonical(idx);
            if let Some(&s) = memo.get(&key) {
                prof.add("dse_memo_hits", 1);
                return s;
            }
            let cand = space.decode(&key);
            if !cand.valid() {
                prof.add("invalid_candidates", 1);
                memo.insert(key, f64::INFINITY);
                return f64::INFINITY;
            }
            let (metrics, walks, oracle_hits) =
                prof.time("candidate_evals", || evaluate_candidate(&cand, cfg, &trace));
            prof.add("graph_walks", walks);
            prof.add("oracle_memo_hits", oracle_hits);
            let scalar = scalarize(cfg, &metrics);
            let scores = cfg.objectives.iter().map(|o| o.score(&metrics)).collect();
            evaluated.push(Evaluated { index: key, candidate: cand, metrics, scores });
            memo.insert(key, scalar);
            scalar
        };
        strategy.search(space, &mut eval);
    }

    let score_vecs: Vec<Vec<f64>> = evaluated.iter().map(|e| e.scores.clone()).collect();
    let mut frontier = pareto_indices(&score_vecs);
    frontier.sort_by(|&a, &b| {
        evaluated[a].scores[0]
            .total_cmp(&evaluated[b].scores[0])
            .then(a.cmp(&b))
    });

    let mut result = DseResult {
        objectives: cfg.objectives.clone(),
        slo: cfg.slo,
        rate,
        evaluated,
        frontier,
        slo_choice: None,
        profile: prof,
    };
    if cfg.slo.is_some() {
        let mut best: Option<usize> = None;
        for i in 0..result.evaluated.len() {
            if !result.meets_slo(i) {
                continue;
            }
            best = match best {
                None => Some(i),
                Some(b) => {
                    let (mi, mb) = (&result.evaluated[i].metrics, &result.evaluated[b].metrics);
                    let better = mi.cost < mb.cost
                        || (mi.cost == mb.cost && mi.slo_ttft < mb.slo_ttft);
                    Some(if better { i } else { b })
                }
            };
        }
        result.slo_choice = best;
    }
    result
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Policy;

    fn tiny_cfg() -> DseConfig {
        let mut cfg = DseConfig::new(LlmConfig::llama2_7b(), Mix::Interactive);
        cfg.requests = 40;
        cfg.seed = 7;
        cfg
    }

    fn tiny_space() -> SearchSpace {
        SearchSpace::paper_point()
            .with_policies(vec![Policy::LeastLoaded])
            .with_devices(vec![1])
            .with_chunks(vec![0, 512])
    }

    #[test]
    fn explore_scores_every_candidate_and_extracts_a_frontier() {
        let cfg = tiny_cfg();
        let res = explore(&tiny_space(), &mut Exhaustive, &cfg);
        assert_eq!(res.evaluated.len(), 2);
        assert!(!res.frontier.is_empty());
        for e in &res.evaluated {
            assert_eq!(e.scores.len(), cfg.objectives.len());
            assert!(e.metrics.throughput_rps > 0.0);
            assert!(e.metrics.ttft_p99 >= e.metrics.ttft_p50);
            assert_eq!(e.metrics.cost, 1.0, "single paper device costs 1.0");
        }
        // no frontier point dominated by any evaluated point
        for &i in &res.frontier {
            assert!(!res
                .evaluated
                .iter()
                .any(|e| dominates(&e.scores, &res.evaluated[i].scores)));
        }
    }

    #[test]
    fn invalid_candidates_are_skipped_not_evaluated() {
        let space = SearchSpace::paper_point()
            .with_policies(vec![Policy::LeastLoaded, Policy::KvAware])
            .with_devices(vec![1]);
        let res = explore(&space, &mut Exhaustive, &tiny_cfg());
        // kvaware on one device is structurally invalid -> only the
        // unified point is evaluated
        assert_eq!(res.evaluated.len(), 1);
        assert_eq!(res.evaluated[0].candidate.policy, Policy::LeastLoaded);
    }

    #[test]
    fn energy_objectives_are_populated_and_rank_halo_first() {
        let mut cfg = tiny_cfg();
        cfg.objectives = vec![Objective::EnergyPerToken, Objective::Throughput];
        let res = explore(&SearchSpace::mapping_extremes(), &mut Exhaustive, &cfg);
        assert_eq!(res.evaluated.len(), 3);
        for e in &res.evaluated {
            assert!(e.metrics.energy_per_token_j > 0.0, "{}", e.candidate.label());
            assert!(e.metrics.total_energy_j > 0.0);
            assert!(e.metrics.peak_power_w > 0.0);
            assert!(e.metrics.edp > 0.0);
        }
        // phase-aware HALO1 picks the cheaper engine per phase, so it
        // must also be the cheapest-energy point of the three extremes
        let best = res.best_by(Objective::EnergyPerToken).unwrap();
        assert_eq!(res.evaluated[best].candidate.composition.name(), "HALO1");
    }

    #[test]
    fn tdp_cap_degrades_throughput_in_the_search() {
        let mut cfg = tiny_cfg();
        cfg.objectives = vec![Objective::Throughput, Objective::PeakPower];
        let space = SearchSpace::paper_point()
            .with_devices(vec![1])
            .with_tdp_caps_w(vec![0.0, 40.0]);
        let res = explore(&space, &mut Exhaustive, &cfg);
        assert_eq!(res.evaluated.len(), 2);
        let free = res.evaluated.iter().find(|e| e.candidate.tdp_w == 0.0).unwrap();
        let capped = res.evaluated.iter().find(|e| e.candidate.tdp_w > 0.0).unwrap();
        assert!(
            capped.metrics.throughput_rps < free.metrics.throughput_rps,
            "a 40 W cap must cost throughput: {} vs {}",
            capped.metrics.throughput_rps,
            free.metrics.throughput_rps
        );
    }

    #[test]
    fn empty_trace_yields_finite_zero_metrics() {
        // regression: energy_per_token / decode_tok_per_s on an empty
        // trace used to flow inf/NaN (or panic in the percentile helper)
        // into total_cmp rankings and report tables
        let trace = Mix::Interactive.trace(1, 0, 5.0);
        assert!(trace.is_empty());
        let space = SearchSpace::paper_point().with_devices(vec![1]);
        let cand = space.decode(&space.first_index());
        let hw = HwConfig::paper();
        let (mut fleet, mut router) = cand.build_fleet(
            &LlmConfig::llama2_7b(),
            &hw,
            4,
            Interconnect::board(),
        );
        let r = fleet.replay(&trace, router.as_mut());
        assert!(r.served.is_empty());
        let m = Metrics::collect(&cand, &r, None);
        for v in [
            m.ttft_p50,
            m.ttft_p99,
            m.e2e_p50,
            m.e2e_p99,
            m.throughput_rps,
            m.decode_tok_per_s,
            m.energy_per_token_j,
            m.total_energy_j,
            m.peak_power_w,
            m.edp,
            m.worst_tenant_ttft_p99,
            m.slo_attainment,
        ] {
            assert!(v.is_finite(), "{m:?}");
        }
        assert_eq!(m.energy_per_token_j, 0.0);
        assert_eq!(m.decode_tok_per_s, 0.0);
        assert_eq!(m.edp, 0.0);
        // and every objective still produces a rankable (non-NaN) score
        for o in Objective::all() {
            assert!(!o.score(&m).is_nan(), "{}", o.name());
        }
    }

    #[test]
    fn dvfs_axis_trades_peak_power_onto_the_edp_frontier() {
        // acceptance: a decode-heavy mix searched over the DVFS ladder
        // keeps a non-nominal point on the EDP frontier — low-frequency
        // decode cuts both energy per token and peak power there
        let mut cfg = DseConfig::new(LlmConfig::llama2_7b(), Mix::Generation);
        cfg.requests = 32;
        cfg.seed = 11;
        cfg.objectives =
            vec![Objective::Edp, Objective::EnergyPerToken, Objective::PeakPower];
        let space = SearchSpace::paper_point()
            .with_devices(vec![1])
            .with_dvfs(vec![(0, 0), (1, 1), (0, 2), (2, 2)]);
        let res = explore(&space, &mut Exhaustive, &cfg);
        assert_eq!(res.evaluated.len(), 4);
        let by_dvfs = |d: (usize, usize)| {
            &res.evaluated.iter().find(|e| e.candidate.dvfs == d).unwrap().metrics
        };
        // peak power falls strictly down the ladder
        let (nom, bal, eco) = (by_dvfs((0, 0)), by_dvfs((1, 1)), by_dvfs((2, 2)));
        assert!(bal.peak_power_w < nom.peak_power_w, "{} vs {}", bal.peak_power_w, nom.peak_power_w);
        assert!(eco.peak_power_w < bal.peak_power_w);
        // decode-heavy: eco decode spends fewer joules per token than
        // nominal (streaming power dwarfs the static-time penalty)
        let split = by_dvfs((0, 2));
        assert!(
            split.energy_per_token_j < nom.energy_per_token_j,
            "{} vs {}",
            split.energy_per_token_j,
            nom.energy_per_token_j
        );
        // ...so the frontier retains at least one non-nominal point
        let frontier_dvfs: Vec<(usize, usize)> =
            res.frontier_points().iter().map(|e| e.candidate.dvfs).collect();
        assert!(
            frontier_dvfs.iter().any(|&d| d != (0, 0)),
            "EDP frontier lost every non-nominal DVFS point: {frontier_dvfs:?}"
        );
    }

    #[test]
    fn explicit_rate_bypasses_calibration() {
        let mut cfg = tiny_cfg();
        cfg.rate = Some(3.5);
        let res = explore(&tiny_space(), &mut Exhaustive, &cfg);
        assert_eq!(res.rate, 3.5);
    }
}

//! Roofline analysis (Fig. 1): arithmetic intensity vs attainable
//! throughput of every GEMM/GEMV in a phase on a given engine.

use crate::arch::MatmulEngine;
use crate::model::OpGraph;

/// One point on the roofline plot.
#[derive(Debug, Clone)]
pub struct RooflinePoint {
    pub kind: &'static str,
    pub m: usize,
    pub k: usize,
    pub n: usize,
    /// FLOP per byte.
    pub intensity: f64,
    /// min(peak, bw * AI), FLOP/s.
    pub attainable_flops: f64,
    /// Whether the op sits in the compute-bound region.
    pub compute_bound: bool,
}

/// Roofline parameters of an engine (FLOP/s peak, B/s slope).
#[derive(Debug, Clone, Copy)]
pub struct Roofline {
    pub peak_flops: f64,
    pub stream_bw: f64,
}

impl Roofline {
    pub fn of(engine: &dyn MatmulEngine) -> Self {
        Roofline { peak_flops: 2.0 * engine.peak_macs(), stream_bw: engine.stream_bw() }
    }

    /// Ridge point: intensity where memory and compute bounds meet.
    pub fn ridge(&self) -> f64 {
        self.peak_flops / self.stream_bw
    }

    pub fn attainable(&self, intensity: f64) -> f64 {
        (intensity * self.stream_bw).min(self.peak_flops)
    }
}

/// Compute roofline points for all matmul ops of a graph.
pub fn roofline_points(graph: &OpGraph, rf: &Roofline, dtype_bytes: usize) -> Vec<RooflinePoint> {
    graph
        .matmul_ops()
        .map(|op| {
            let ai = op.arithmetic_intensity(dtype_bytes);
            RooflinePoint {
                kind: op.kind.name(),
                m: op.m,
                k: op.k,
                n: op.n,
                intensity: ai,
                attainable_flops: rf.attainable(ai),
                compute_bound: ai >= rf.ridge(),
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::cim::CimEngine;
    use crate::config::HwConfig;
    use crate::model::{build_decode_graph, build_prefill_graph, LlmConfig};

    #[test]
    fn fig1_prefill_compute_bound_decode_memory_bound() {
        // the paper's Fig. 1: L_in=512 prefill GEMMs approach the compute
        // roof; decode (BS=1) ops are all memory-bound
        let hw = HwConfig::paper();
        let m = LlmConfig::llama2_7b();
        let rf = Roofline::of(&CimEngine::new(&hw));
        assert!(rf.ridge() > 10.0 && rf.ridge() < 500.0, "ridge {}", rf.ridge());

        let pre = roofline_points(&build_prefill_graph(&m, 512, 1), &rf, 1);
        let weight_gemms: Vec<_> = pre
            .iter()
            .filter(|p| !matches!(p.kind, "attn_score" | "attn_value" | "lm_head"))
            .collect();
        assert!(weight_gemms.iter().all(|p| p.compute_bound), "{weight_gemms:?}");

        let dec = roofline_points(&build_decode_graph(&m, 512, 1), &rf, 1);
        assert!(dec.iter().all(|p| !p.compute_bound));
    }

    #[test]
    fn fig1_bs16_attention_stays_memory_bound() {
        // batching pushes weight GEMVs toward compute; attention stays
        // memory-bound (per-sequence KV)
        let hw = HwConfig::paper();
        let m = LlmConfig::llama2_7b();
        let rf = Roofline::of(&CimEngine::new(&hw));
        let dec = roofline_points(&build_decode_graph(&m, 512, 16), &rf, 1);
        for p in &dec {
            if matches!(p.kind, "attn_score" | "attn_value") {
                assert!(!p.compute_bound, "{p:?}");
                assert!(p.intensity < 5.0);
            }
        }
        // weight ops at BS=16 have 16x the intensity of BS=1
        let b1 = roofline_points(&build_decode_graph(&m, 512, 1), &rf, 1);
        let ai = |pts: &[RooflinePoint], kind: &str| {
            pts.iter().find(|p| p.kind == kind).unwrap().intensity
        };
        let r = ai(&dec, "ffn_up") / ai(&b1, "ffn_up");
        assert!(r > 10.0 && r < 18.0, "{r}");
    }

    #[test]
    fn attainable_clamps_at_peak() {
        let rf = Roofline { peak_flops: 100.0, stream_bw: 10.0 };
        assert_eq!(rf.ridge(), 10.0);
        assert_eq!(rf.attainable(5.0), 50.0);
        assert_eq!(rf.attainable(50.0), 100.0);
    }
}

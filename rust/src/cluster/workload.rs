//! Named scenario mixes layered on the Poisson trace machinery.
//!
//! Each mix is a distribution over (prompt length, output length) pairs —
//! log-uniform within a band, mirroring `poisson_trace` — chosen to stress
//! a different side of the prefill/decode dichotomy:
//!
//! * **chat**: short-in / short-out — balanced, latency-sensitive;
//! * **summarization**: long-in / short-out — prefill-dominated;
//! * **generation**: short-in / long-out — decode-dominated;
//! * **interactive**: a 50/25/25 blend of the three.

use crate::sim::queueing::{log_uniform, trace_with, TraceRequest};
use crate::util::Rng;

/// Named workload mix.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Mix {
    Chat,
    Summarization,
    Generation,
    Interactive,
}

impl Mix {
    pub fn all() -> [Mix; 4] {
        [Mix::Chat, Mix::Summarization, Mix::Generation, Mix::Interactive]
    }

    pub fn name(&self) -> &'static str {
        match self {
            Mix::Chat => "chat",
            Mix::Summarization => "summarization",
            Mix::Generation => "generation",
            Mix::Interactive => "interactive",
        }
    }

    pub fn by_name(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "chat" => Some(Mix::Chat),
            "summarization" | "summarize" | "sum" => Some(Mix::Summarization),
            "generation" | "gen" => Some(Mix::Generation),
            "interactive" | "mixed" | "blend" => Some(Mix::Interactive),
            _ => None,
        }
    }

    /// (l_in, l_out) bands: short-in/short-out, long-in/short-out,
    /// short-in/long-out.
    fn sample(&self, rng: &mut Rng) -> (usize, usize) {
        match self {
            Mix::Chat => (log_uniform(rng, 64, 512), log_uniform(rng, 64, 256)),
            Mix::Summarization => (log_uniform(rng, 2048, 8192), log_uniform(rng, 32, 128)),
            Mix::Generation => (log_uniform(rng, 64, 256), log_uniform(rng, 512, 2048)),
            Mix::Interactive => {
                let u = rng.f64();
                if u < 0.5 {
                    Mix::Chat.sample(rng)
                } else if u < 0.75 {
                    Mix::Summarization.sample(rng)
                } else {
                    Mix::Generation.sample(rng)
                }
            }
        }
    }

    /// Poisson-arrival trace of `n` requests from this mix.
    pub fn trace(&self, seed: u64, n: usize, rate_per_s: f64) -> Vec<TraceRequest> {
        trace_with(seed, n, rate_per_s, |rng| self.sample(rng))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn traces_respect_bands() {
        let tr = Mix::Chat.trace(1, 500, 10.0);
        assert_eq!(tr.len(), 500);
        assert!(tr.iter().all(|r| (64..=512).contains(&r.l_in) && (64..=256).contains(&r.l_out)));
        let tr = Mix::Summarization.trace(2, 500, 10.0);
        assert!(tr.iter().all(|r| r.l_in >= 2048 && r.l_out <= 128));
        let tr = Mix::Generation.trace(3, 500, 10.0);
        assert!(tr.iter().all(|r| r.l_in <= 256 && r.l_out >= 512));
    }

    #[test]
    fn interactive_blends_all_three() {
        let tr = Mix::Interactive.trace(7, 2000, 10.0);
        let sum = tr.iter().filter(|r| r.l_in >= 2048).count();
        let gen = tr.iter().filter(|r| r.l_out >= 512).count();
        let chat = tr.iter().filter(|r| r.l_in <= 512 && r.l_out <= 256).count();
        // 50/25/25 split with slack
        assert!((800..=1200).contains(&chat), "{chat}");
        assert!((300..=700).contains(&sum), "{sum}");
        assert!((300..=700).contains(&gen), "{gen}");
        // arrivals strictly increase (Poisson machinery intact)
        assert!(tr.windows(2).all(|w| w[0].arrival < w[1].arrival));
    }

    #[test]
    fn deterministic_per_seed() {
        let a = Mix::Interactive.trace(9, 100, 5.0);
        let b = Mix::Interactive.trace(9, 100, 5.0);
        assert!(a.iter().zip(&b).all(|(x, y)| {
            x.arrival == y.arrival && x.l_in == y.l_in && x.l_out == y.l_out
        }));
        let c = Mix::Interactive.trace(10, 100, 5.0);
        assert!(a.iter().zip(&c).any(|(x, y)| x.l_in != y.l_in || x.arrival != y.arrival));
    }

    #[test]
    fn by_name_roundtrip() {
        for m in Mix::all() {
            assert_eq!(Mix::by_name(m.name()), Some(m));
        }
        assert!(Mix::by_name("batch").is_none());
    }
}

//! Multi-objective scoring of an evaluated candidate.
//!
//! A replay yields a [`Metrics`] record; each [`Objective`] reads one
//! scalar out of it with a direction (minimize latency/cost/evictions,
//! maximize throughput/SLO attainment). [`Objective::score`] folds the
//! direction in — scores are always *minimized* — so the Pareto machinery
//! and the scalar search guidance never need to know about directions.

use super::space::Candidate;
use crate::cluster::{per_tenant_stats_served, FleetResult};

/// Everything the objectives can read about one evaluated candidate.
#[derive(Debug, Clone, PartialEq)]
pub struct Metrics {
    pub ttft_p50: f64,
    pub ttft_p99: f64,
    pub e2e_p50: f64,
    pub e2e_p99: f64,
    /// Served requests per second over the makespan.
    pub throughput_rps: f64,
    /// Generated (decode) tokens per second over the makespan.
    pub decode_tok_per_s: f64,
    pub utilization: f64,
    pub evictions: f64,
    pub recompute_tokens: f64,
    pub kv_transfer_gb: f64,
    /// Worst per-tenant TTFT p99 (equals `ttft_p99` for 1 tenant).
    pub worst_tenant_ttft_p99: f64,
    /// TTFT at the SLO percentile (p50 unless configured otherwise).
    pub slo_ttft: f64,
    /// Fraction of requests whose TTFT met the SLO (1.0 when no SLO set).
    pub slo_attainment: f64,
    /// Relative fleet cost of the candidate (see [`fleet_cost`]).
    pub cost: f64,
    /// Fleet energy per generated token, J (power plane).
    pub energy_per_token_j: f64,
    /// Total fleet energy over the makespan, J.
    pub total_energy_j: f64,
    /// Highest mean event power across the fleet's devices, W.
    pub peak_power_w: f64,
    /// Energy-delay product: energy per token x median e2e latency
    /// (J*s; jointly penalizes inefficient and slow configurations).
    pub edp: f64,
}

impl Metrics {
    /// Collect metrics from a finished replay or streamed serve. All
    /// inputs come off the [`FleetResult`] itself (token totals and
    /// tenant identity travel on the served records now), so no
    /// materialized trace is needed. `slo` is the optional
    /// (ttft_seconds, percentile) SLO spec used for `slo_ttft` /
    /// `slo_attainment`.
    pub fn collect(cand: &Candidate, r: &FleetResult, slo: Option<(f64, f64)>) -> Metrics {
        let total_tokens = r.tokens;
        let tenants = per_tenant_stats_served(&r.served, r.makespan);
        let worst_tenant =
            tenants.iter().map(|t| t.ttft_p99).fold(0.0f64, f64::max);
        let pct = slo.map_or(50.0, |(_, p)| p);
        let slo_ttft = r.ttft_pct(pct);
        let slo_attainment = match slo {
            None => 1.0,
            Some((target, _)) => {
                let met = r.served.iter().filter(|s| s.ttft <= target).count();
                met as f64 / r.served.len().max(1) as f64
            }
        };
        let energy_per_token_j = r.energy_per_token(total_tokens);
        // an empty (or fully rejected) trace must yield finite zeros, not
        // inf/NaN that poison `total_cmp` rankings and report tables
        let decode_tok_per_s = if r.requests == 0 {
            0.0
        } else {
            total_tokens as f64 / r.makespan.max(1e-12)
        };
        Metrics {
            ttft_p50: r.ttft_p50(),
            ttft_p99: r.ttft_p99(),
            e2e_p50: r.e2e_p50(),
            e2e_p99: r.e2e_p99(),
            throughput_rps: r.throughput_rps(),
            decode_tok_per_s,
            utilization: r.utilization(),
            evictions: r.evictions as f64,
            recompute_tokens: r.recompute_tokens as f64,
            kv_transfer_gb: r.kv_bytes as f64 / 1e9,
            worst_tenant_ttft_p99: worst_tenant,
            slo_ttft,
            slo_attainment,
            cost: fleet_cost(cand),
            energy_per_token_j,
            total_energy_j: r.energy_j(),
            peak_power_w: r.peak_power_w,
            edp: energy_per_token_j * r.e2e_p50(),
        }
    }
}

/// Relative fleet cost of a candidate: device count scaled by the
/// per-device premium of its hardware knobs. CALIBRATED proxy (the paper
/// gives no $ figures): the CiM die is tile-dominated, so doubling the
/// tile mesh adds ~35% of a device; a wider interposer is cheap (~10%
/// per extra unit of bandwidth scale). Good enough to make "cheapest
/// config meeting the SLO" a meaningful query.
pub fn fleet_cost(c: &Candidate) -> f64 {
    let tile_premium = 0.35 * (c.tile_scale.saturating_sub(1)) as f64;
    let link_premium = 0.10 * (c.interposer_scale - 1.0).max(0.0);
    c.devices as f64 * (1.0 + tile_premium + link_premium)
}

/// Optimization direction of an objective.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Direction {
    Minimize,
    Maximize,
}

/// One scored dimension of the search.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Objective {
    TtftP50,
    TtftP99,
    E2eP50,
    E2eP99,
    /// Served requests per second (maximize).
    Throughput,
    /// Generated tokens per second (maximize).
    DecodeThroughput,
    /// KV-pressure evictions (minimize).
    Evictions,
    /// Relative fleet cost (minimize).
    Cost,
    /// Fraction of requests meeting the TTFT SLO (maximize).
    SloAttainment,
    /// Worst per-tenant TTFT p99 (minimize; multi-tenant fairness).
    WorstTenantTtft,
    /// Fleet energy per generated token (minimize; power plane).
    EnergyPerToken,
    /// Energy-delay product: energy/token x median e2e (minimize).
    Edp,
    /// Highest per-package event power (minimize; TDP headroom).
    PeakPower,
}

impl Objective {
    pub fn all() -> [Objective; 13] {
        [
            Objective::TtftP50,
            Objective::TtftP99,
            Objective::E2eP50,
            Objective::E2eP99,
            Objective::Throughput,
            Objective::DecodeThroughput,
            Objective::Evictions,
            Objective::Cost,
            Objective::SloAttainment,
            Objective::WorstTenantTtft,
            Objective::EnergyPerToken,
            Objective::Edp,
            Objective::PeakPower,
        ]
    }

    /// The default search objectives: latency (median + tail),
    /// throughput, and cost — the axes of the paper's own §V-B argument.
    pub fn default_set() -> Vec<Objective> {
        vec![Objective::TtftP50, Objective::TtftP99, Objective::Throughput, Objective::Cost]
    }

    pub fn name(&self) -> &'static str {
        match self {
            Objective::TtftP50 => "ttft_p50",
            Objective::TtftP99 => "ttft_p99",
            Objective::E2eP50 => "e2e_p50",
            Objective::E2eP99 => "e2e_p99",
            Objective::Throughput => "throughput",
            Objective::DecodeThroughput => "decode_tput",
            Objective::Evictions => "evictions",
            Objective::Cost => "cost",
            Objective::SloAttainment => "slo_attainment",
            Objective::WorstTenantTtft => "tenant_ttft_p99",
            Objective::EnergyPerToken => "energy_per_token",
            Objective::Edp => "edp",
            Objective::PeakPower => "peak_power",
        }
    }

    pub fn by_name(s: &str) -> Option<Objective> {
        let norm: String =
            s.to_ascii_lowercase().chars().filter(|c| *c != '-' && *c != '_').collect();
        match norm.as_str() {
            "ttftp50" | "ttft" => Some(Objective::TtftP50),
            "ttftp99" => Some(Objective::TtftP99),
            "e2ep50" | "e2e" => Some(Objective::E2eP50),
            "e2ep99" => Some(Objective::E2eP99),
            "throughput" | "rps" => Some(Objective::Throughput),
            "decodetput" | "tokens" | "tokpersec" => Some(Objective::DecodeThroughput),
            "evictions" => Some(Objective::Evictions),
            "cost" => Some(Objective::Cost),
            "sloattainment" | "slo" => Some(Objective::SloAttainment),
            "tenantttftp99" | "tenantttft" | "fairness" => Some(Objective::WorstTenantTtft),
            "energypertoken" | "energy" | "ept" | "joulespertoken" => {
                Some(Objective::EnergyPerToken)
            }
            "edp" | "energydelay" => Some(Objective::Edp),
            "peakpower" | "peak" | "watts" => Some(Objective::PeakPower),
            _ => None,
        }
    }

    pub fn direction(&self) -> Direction {
        match self {
            Objective::Throughput
            | Objective::DecodeThroughput
            | Objective::SloAttainment => Direction::Maximize,
            _ => Direction::Minimize,
        }
    }

    /// The raw metric value (in its natural direction, for reporting).
    pub fn value(&self, m: &Metrics) -> f64 {
        match self {
            Objective::TtftP50 => m.ttft_p50,
            Objective::TtftP99 => m.ttft_p99,
            Objective::E2eP50 => m.e2e_p50,
            Objective::E2eP99 => m.e2e_p99,
            Objective::Throughput => m.throughput_rps,
            Objective::DecodeThroughput => m.decode_tok_per_s,
            Objective::Evictions => m.evictions,
            Objective::Cost => m.cost,
            Objective::SloAttainment => m.slo_attainment,
            Objective::WorstTenantTtft => m.worst_tenant_ttft_p99,
            Objective::EnergyPerToken => m.energy_per_token_j,
            Objective::Edp => m.edp,
            Objective::PeakPower => m.peak_power_w,
        }
    }

    /// The minimized coordinate fed to the Pareto machinery.
    pub fn score(&self, m: &Metrics) -> f64 {
        match self.direction() {
            Direction::Minimize => self.value(m),
            Direction::Maximize => -self.value(m),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dse::space::SearchSpace;

    #[test]
    fn by_name_roundtrip() {
        for o in Objective::all() {
            assert_eq!(Objective::by_name(o.name()), Some(o), "{}", o.name());
        }
        assert!(Objective::by_name("accuracy").is_none());
    }

    #[test]
    fn default_set_spans_three_plus_objectives() {
        assert!(Objective::default_set().len() >= 3);
    }

    #[test]
    fn cost_monotone_in_devices_and_tiles() {
        let space = SearchSpace::paper_point();
        let base = space.decode(&space.first_index());
        let mut more_devices = base.clone();
        more_devices.devices *= 2;
        assert!(fleet_cost(&more_devices) > fleet_cost(&base));
        let mut more_tiles = base.clone();
        more_tiles.tile_scale = 2;
        assert!(fleet_cost(&more_tiles) > fleet_cost(&base));
        let mut fat_link = base.clone();
        fat_link.interposer_scale = 2.0;
        assert!(fleet_cost(&fat_link) > fleet_cost(&base));
        // and a narrower link never goes below the device floor
        let mut thin_link = base.clone();
        thin_link.interposer_scale = 0.5;
        assert!(fleet_cost(&thin_link) >= base.devices as f64);
    }

    #[test]
    fn maximize_objectives_negate_into_scores() {
        let space = SearchSpace::paper_point();
        let cand = space.decode(&space.first_index());
        let m = Metrics {
            ttft_p50: 0.1,
            ttft_p99: 0.5,
            e2e_p50: 1.0,
            e2e_p99: 2.0,
            throughput_rps: 30.0,
            decode_tok_per_s: 9000.0,
            utilization: 0.8,
            evictions: 3.0,
            recompute_tokens: 600.0,
            kv_transfer_gb: 1.5,
            worst_tenant_ttft_p99: 0.6,
            slo_ttft: 0.1,
            slo_attainment: 0.95,
            cost: fleet_cost(&cand),
            energy_per_token_j: 0.05,
            total_energy_j: 450.0,
            peak_power_w: 160.0,
            edp: 0.05,
        };
        assert_eq!(Objective::Throughput.score(&m), -30.0);
        assert_eq!(Objective::TtftP50.score(&m), 0.1);
        assert_eq!(Objective::SloAttainment.score(&m), -0.95);
        // the power objectives all minimize their raw values
        assert_eq!(Objective::EnergyPerToken.score(&m), 0.05);
        assert_eq!(Objective::PeakPower.score(&m), 160.0);
        assert_eq!(Objective::Edp.score(&m), 0.05);
    }
}

//! Minimal JSON parser/serializer (serde is unavailable offline).
//!
//! Supports the full JSON grammar minus exotic number forms; used to read
//! `artifacts/manifest.json` and to emit machine-readable reports.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(s: &str) -> Result<Json, String> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(format!("trailing data at byte {}", p.i));
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `obj["a"]["b"][2]`-style path access: keys and numeric indices.
    pub fn path(&self, path: &[&str]) -> Option<&Json> {
        let mut cur = self;
        for p in path {
            cur = match cur {
                Json::Obj(m) => m.get(*p)?,
                Json::Arr(a) => a.get(p.parse::<usize>().ok()?)?,
                _ => return None,
            };
        }
        Some(cur)
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Convenience: array of usize.
    pub fn as_usize_vec(&self) -> Option<Vec<usize>> {
        self.as_arr()?.iter().map(|v| v.as_usize()).collect()
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {:?} at byte {}", other.map(|c| c as char), self.i)),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-') {
                self.i += 1;
            } else {
                break;
            }
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek().ok_or("unterminated string")? {
                b'"' => {
                    self.i += 1;
                    return Ok(out);
                }
                b'\\' => {
                    self.i += 1;
                    let c = self.peek().ok_or("bad escape")?;
                    self.i += 1;
                    match c {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            // bounds-checked: a truncated `\uXX` at end of
                            // input is a parse error, not a slice panic
                            let end = self
                                .i
                                .checked_add(4)
                                .filter(|&e| e <= self.b.len())
                                .ok_or("truncated \\u escape")?;
                            let hex = std::str::from_utf8(&self.b[self.i..end])
                                .map_err(|_| "bad \\u")?;
                            let cp = u32::from_str_radix(hex, 16).map_err(|_| "bad \\u")?;
                            self.i = end;
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        }
                        _ => return Err(format!("bad escape \\{}", c as char)),
                    }
                }
                c => {
                    // copy a UTF-8 run verbatim
                    let start = self.i;
                    let mut j = self.i;
                    while j < self.b.len() && self.b[j] != b'"' && self.b[j] != b'\\' {
                        j += 1;
                    }
                    out.push_str(
                        std::str::from_utf8(&self.b[start..j]).map_err(|_| "bad utf8")?,
                    );
                    self.i = j;
                    let _ = c;
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(format!("expected , or ] at byte {}", self.i)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(format!("expected , or }} at byte {}", self.i)),
            }
        }
    }
}

fn escape(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut s = String::new();
        write_json(self, &mut s);
        f.write_str(&s)
    }
}

fn write_json(v: &Json, out: &mut String) {
    match v {
        Json::Null => out.push_str("null"),
        Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Json::Num(n) => {
            if n.fract() == 0.0 && n.abs() < 1e15 {
                out.push_str(&format!("{}", *n as i64));
            } else {
                out.push_str(&format!("{n}"));
            }
        }
        Json::Str(s) => escape(s, out),
        Json::Arr(a) => {
            out.push('[');
            for (i, x) in a.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_json(x, out);
            }
            out.push(']');
        }
        Json::Obj(m) => {
            out.push('{');
            for (i, (k, x)) in m.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                escape(k, out);
                out.push(':');
                write_json(x, out);
            }
            out.push('}');
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse(r#""a\nb""#).unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(j.path(&["a", "2", "b"]).unwrap().as_str(), Some("x"));
        assert_eq!(j.path(&["a", "0"]).unwrap().as_f64(), Some(1.0));
        assert_eq!(j.get("c"), Some(&Json::Null));
    }

    #[test]
    fn parse_unicode_escape() {
        let j = Json::parse(r#""Aé""#).unwrap();
        assert_eq!(j.as_str(), Some("Aé"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("nulll").is_err());
    }

    #[test]
    fn truncated_unicode_escape_errors_instead_of_panicking() {
        // regression: these used to slice out of bounds on user input
        assert!(Json::parse(r#""\u"#).is_err());
        assert!(Json::parse(r#""\u00"#).is_err());
        assert!(Json::parse(r#""\u12"#).is_err());
        // a complete escape still parses
        assert_eq!(Json::parse(r#""A""#).unwrap(), Json::Str("A".into()));
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"entries":{"decode_b4":{"hlo":"decode_b4.hlo.txt","inputs":[{"dtype":"i32","shape":[4]}]}},"n":-2.5}"#;
        let j = Json::parse(src).unwrap();
        let j2 = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn whitespace_tolerant() {
        let j = Json::parse(" {\n\t\"a\" :\r [ ] } ").unwrap();
        assert_eq!(j.path(&["a"]).unwrap().as_arr().unwrap().len(), 0);
    }

    #[test]
    fn usize_vec() {
        let j = Json::parse("[1, 2, 3]").unwrap();
        assert_eq!(j.as_usize_vec(), Some(vec![1, 2, 3]));
    }
}

//! Observability plane: request-lifecycle tracing, streaming metrics,
//! and the simulator's own performance trajectory.
//!
//! Three concerns, one module:
//!
//! - **Spans** ([`span`]): per-device request-lifecycle recording
//!   (queued → prefill chunks → KV handoff → decode steps → done, plus
//!   evictions and throttle events), exportable as a Chrome-trace /
//!   Perfetto JSON timeline. Recording is strictly opt-in
//!   (`Device::enable_obs` / `Fleet::enable_obs`) and copies the same
//!   `f64`s that advance the simulation clock, so enabling it changes
//!   no simulated result — bit for bit.
//! - **Metrics** ([`registry`], [`hist`], [`snapshot`]): counters,
//!   gauges and fixed-memory log-bucketed histograms behind one
//!   registry, serialized as versioned snapshots for the CLI `--json`
//!   surfaces.
//! - **Self-profiling** ([`selfprof`], [`bench`]): host wall-time and
//!   work counters for the simulator's own hot paths, plus the pinned
//!   `halo bench` suite CI tracks commit over commit.
//! - **Time-resolved telemetry** ([`timeseries`], [`slo`], [`attrib`]):
//!   fixed-memory windowed metrics over *simulated* time with
//!   coarsening, per-window SLO attainment with multi-window burn-rate
//!   alerting, and per-request latency attribution whose components
//!   fold bit-exactly onto the recorded TTFT/e2e — the `halo monitor`
//!   surface and the signal a future autoscaler consumes.
//! - **Causal critical paths** ([`critpath`], [`whatif`]): per-request
//!   critical-path extraction classifying every segment by binding
//!   resource (CiM compute / CiD bandwidth / interconnect / KV
//!   capacity / scheduler / thermal), aggregated into fleet bottleneck
//!   profiles, plus a COZ-style what-if engine that re-folds the paths
//!   under scaled resources — the `halo critpath` surface and the
//!   control signal the KV-spill and packing DSE tentpoles consume.
//!
//! Simulated quantities and host measurements never mix: wall times
//! live only in [`SelfProfile`] / [`bench`] outputs and are excluded
//! from every determinism guarantee.

pub mod attrib;
pub mod bench;
pub mod critpath;
pub mod hist;
pub mod registry;
pub mod selfprof;
pub mod slo;
pub mod snapshot;
pub mod span;
pub mod timeseries;
pub mod whatif;

pub use attrib::{attribute, reconcile, tail_breakdown, Attribution, BreakdownRow};
pub use bench::{bench_json, compare, peak_rss_bytes, run_pinned, BenchDelta, BenchPoint};
pub use critpath::{
    bottleneck_profile, extract_paths, phase_profile, reconcile_paths, windowed_profile,
    BottleneckRow, CritPath, PhaseRow, Resource, Segment, WindowProfile, N_RESOURCES,
};
pub use hist::LogHistogram;
pub use registry::{fleet_registry, timeseries_registry, Registry};
pub use selfprof::SelfProfile;
pub use slo::{attainment, bad_fraction, BurnRateConfig, SloAlert, SloReport, SloSpec, WindowSlo};
pub use snapshot::{
    cluster_snapshot, critpath_snapshot, dse_snapshot, metrics_json, timeseries_snapshot,
};
pub use span::{chrome_trace, BatchRecord, Event, EventKind, Recorder, Span, SpanKind, Track};
pub use timeseries::{DeviceGauges, GaugeSample, Window, WindowSeries};
pub use whatif::{evaluate_all, scaled_latencies, standard_whatifs, WhatIf, WhatIfResult};

use crate::util::json::Json;
use std::collections::BTreeMap;

/// A JSON object from `(key, value)` pairs — the snapshot builders'
/// shorthand (`Json::Obj` wants an owned `BTreeMap<String, _>`).
pub fn jobj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect::<BTreeMap<_, _>>())
}

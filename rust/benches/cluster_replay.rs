//! Microbenchmarks of the cluster plane: trace generation, single-device
//! cycle stepping, and whole-fleet replays under each routing policy.
//! The fleet replay loop is the hot path the `halo cluster` CLI and the
//! cluster report tables sit on.

use halo::cluster::{Interconnect, Mix, Policy, SchedConfig};
use halo::config::HwConfig;
use halo::model::LlmConfig;
use halo::sim::queueing::{replay_trace, replay_trace_with};
use halo::mapping::MappingKind;
use halo::util::bench::{bb, BenchSuite};

fn main() {
    let hw = HwConfig::paper();
    let llm = LlmConfig::llama2_7b();
    let mut s = BenchSuite::new("cluster_replay");

    s.bench("interactive_trace_1k", || {
        bb(Mix::Interactive.trace(7, 1000, 50.0));
    });

    // the refactored single-device core (regression guard vs the fleet)
    let tr1 = Mix::Chat.trace(11, 96, 1.0e6);
    s.bench_throughput("replay_trace_single_device_burst", tr1.len() as f64, || {
        bb(replay_trace(&llm, &hw, MappingKind::Halo1, 8, &tr1));
    });

    let trace = Mix::Interactive.trace(13, 160, 40.0);
    for policy in Policy::all() {
        let name = format!("fleet8_replay_{}", policy.name());
        s.bench_throughput(&name, trace.len() as f64, || {
            let (mut fleet, mut router) =
                policy.build(&llm, &hw, 8, 8, 0.5, Interconnect::board());
            bb(fleet.replay(&trace, router.as_mut()));
        });
    }

    // disaggregated replay with an interconnect slow enough that KV
    // transfers dominate (more in-flight handoffs -> more events)
    s.bench_throughput("fleet8_replay_disaggregated_wan", trace.len() as f64, || {
        let (mut fleet, mut router) =
            Policy::PhaseDisaggregated.build(&llm, &hw, 8, 8, 0.5, Interconnect::wan());
        bb(fleet.replay(&trace, router.as_mut()));
    });

    // chunked prefill multiplies scheduling cycles (one chunk per prompt
    // per cycle) — the scheduler's own hot path
    s.bench_throughput("replay_single_device_chunked512", tr1.len() as f64, || {
        bb(replay_trace_with(
            &llm,
            &hw,
            MappingKind::Halo1,
            8,
            SchedConfig::chunked(512),
            &tr1,
        ));
    });

    // KV-capped decode pool: eviction/recompute churn plus the
    // capacity-aware router's headroom scans
    s.bench_throughput("fleet4_replay_kvaware_capped", trace.len() as f64, || {
        let sched = SchedConfig::default().with_kv_capacity(4_000_000_000);
        let (mut fleet, mut router) =
            Policy::KvAware.build_with(&llm, &hw, 4, 8, 0.5, Interconnect::board(), sched);
        bb(fleet.replay(&trace, router.as_mut()));
    });

    s.finish();
}

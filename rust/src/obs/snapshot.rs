//! Versioned JSON snapshots for the `--json` CLI surfaces.
//!
//! Each snapshot carries a `schema` tag (`halo.cluster.v1`,
//! `halo.dse.v1`) so downstream tooling can dispatch on shape instead of
//! sniffing fields. Simulated quantities come from the [`Registry`] /
//! replay results; host wall times ride along under `profile` and are
//! explicitly measurement metadata, not simulation output.

use super::critpath::{
    BottleneckRow, CritPath, PhaseRow, Resource, Segment, WindowProfile, N_RESOURCES,
};
use super::registry::fleet_registry;
use super::slo::SloReport;
use super::timeseries::WindowSeries;
use super::whatif::WhatIfResult;
use super::{jobj, SelfProfile};
use crate::cluster::fleet::{DeviceSummary, FleetResult};
use crate::dse::{DseResult, Metrics};
use crate::util::json::Json;

/// Observability drop counters as a snapshot object: spans/events/
/// decode-batch records discarded past the recorder retention cap.
/// `None` (obs off) serializes as JSON null so downstream tooling can
/// tell "not instrumented" from "instrumented and lossless".
fn dropped_json(obs_dropped: Option<(u64, u64, u64)>) -> Json {
    match obs_dropped {
        None => Json::Null,
        Some((spans, events, batches)) => jobj(vec![
            ("spans", Json::Num(spans as f64)),
            ("events", Json::Num(events as f64)),
            ("batches", Json::Num(batches as f64)),
        ]),
    }
}

/// One replayed cluster as a machine-readable snapshot. `config` is the
/// caller-described setup (fleet shape, workload, seed) echoed back so
/// the artifact is self-contained. `obs_dropped` carries the recorder
/// drop counters when the replay was instrumented (`None` otherwise).
pub fn cluster_snapshot(
    r: &FleetResult,
    walks: u64,
    memo_hits: u64,
    profile: &SelfProfile,
    config: Json,
    obs_dropped: Option<(u64, u64, u64)>,
) -> Json {
    let per_device: Vec<Json> =
        r.per_device.iter().map(|d| device_json(d, r.makespan)).collect();
    jobj(vec![
        ("schema", Json::Str("halo.cluster.v1".to_string())),
        ("config", config),
        ("metrics", fleet_registry(r, walks, memo_hits).to_json()),
        ("per_device", Json::Arr(per_device)),
        ("obs_dropped", dropped_json(obs_dropped)),
        ("profile", profile.to_json()),
    ])
}

fn device_json(d: &DeviceSummary, makespan: f64) -> Json {
    jobj(vec![
        ("id", Json::Num(d.id as f64)),
        ("mapping", Json::Str(d.mapping.name().to_string())),
        ("role", Json::Str(d.role.to_string())),
        ("prefills", Json::Num(d.prefills as f64)),
        ("decode_steps", Json::Num(d.decode_steps as f64)),
        ("served", Json::Num(d.served as f64)),
        ("busy_s", Json::Num(d.busy)),
        ("utilization", Json::Num(d.utilization(makespan))),
        ("evictions", Json::Num(d.evictions as f64)),
        ("recompute_tokens", Json::Num(d.recompute_tokens as f64)),
        ("kv_peak_bytes", Json::Num(d.kv_peak as f64)),
        ("energy_j", Json::Num(d.energy.total())),
        ("peak_power_w", Json::Num(d.peak_power_w)),
        ("throttled_s", Json::Num(d.throttled_s)),
    ])
}

/// One finished exploration as a machine-readable snapshot.
pub fn dse_snapshot(res: &DseResult, config: Json) -> Json {
    let objectives: Vec<Json> =
        res.objectives.iter().map(|o| Json::Str(o.name().to_string())).collect();
    let slo = match res.slo {
        None => Json::Null,
        Some(s) => jobj(vec![("ttft_s", Json::Num(s.ttft)), ("pct", Json::Num(s.pct))]),
    };
    let evaluated: Vec<Json> = res
        .evaluated
        .iter()
        .map(|e| {
            jobj(vec![
                ("label", Json::Str(e.candidate.label())),
                ("scores", Json::Arr(e.scores.iter().map(|s| Json::Num(*s)).collect())),
                ("metrics", metrics_json(&e.metrics)),
            ])
        })
        .collect();
    let frontier: Vec<Json> = res.frontier.iter().map(|&i| Json::Num(i as f64)).collect();
    jobj(vec![
        ("schema", Json::Str("halo.dse.v1".to_string())),
        ("config", config),
        ("rate_rps", Json::Num(res.rate)),
        ("objectives", Json::Arr(objectives)),
        ("slo", slo),
        ("evaluated", Json::Arr(evaluated)),
        ("frontier", Json::Arr(frontier)),
        (
            "slo_choice",
            res.slo_choice.map_or(Json::Null, |i| Json::Num(i as f64)),
        ),
        ("profile", res.profile.to_json()),
    ])
}

/// One monitored serve's windowed telemetry as a machine-readable
/// `halo.timeseries.v1` snapshot: the config echo, the window series,
/// the merged whole-run latency populations (bit-identical to the
/// `FleetResult` histograms — pinned by test), and the SLO burn-rate
/// report when one was evaluated.
pub fn timeseries_snapshot(
    series: &WindowSeries,
    slo: Option<&SloReport>,
    config: Json,
    obs_dropped: Option<(u64, u64, u64)>,
) -> Json {
    jobj(vec![
        ("schema", Json::Str("halo.timeseries.v1".to_string())),
        ("config", config),
        ("series", series.to_json()),
        ("ttft_total", series.merged_ttft().to_json()),
        ("e2e_total", series.merged_e2e().to_json()),
        ("obs_dropped", dropped_json(obs_dropped)),
        ("slo", slo.map_or(Json::Null, SloReport::to_json)),
    ])
}

fn segment_json(s: &Segment) -> Json {
    jobj(vec![
        ("label", Json::Str(s.label.to_string())),
        ("resource", Json::Str(s.resource.name().to_string())),
        ("phase", Json::Str(s.phase.to_string())),
        ("start_s", Json::Num(s.start)),
        ("dur_s", Json::Num(s.dur)),
    ])
}

fn path_json(p: &CritPath) -> Json {
    jobj(vec![
        ("arrival_s", Json::Num(p.arrival)),
        ("ttft_s", Json::Num(p.ttft)),
        ("e2e_s", Json::Num(p.e2e)),
        ("coverage", Json::Num(p.coverage)),
        ("segments", Json::Arr(p.segments.iter().map(segment_json).collect())),
    ])
}

fn resource_totals_json(totals: &[f64; N_RESOURCES]) -> Json {
    jobj(Resource::ALL.iter().map(|r| (r.name(), Json::Num(totals[r.index()]))).collect())
}

/// One critical-path analysis as a machine-readable `halo.critpath.v1`
/// snapshot: the config echo, population/reconciliation/coverage
/// summary, the per-resource bottleneck profile (whole population and
/// p99 tail), the per-phase profile, per-window resource totals, the
/// what-if table, and the `top_paths` slowest per-request path dumps.
#[allow(clippy::too_many_arguments)]
pub fn critpath_snapshot(
    paths: &[CritPath],
    mismatches: usize,
    bottleneck: &[BottleneckRow],
    phases: &[PhaseRow],
    windows: &[WindowProfile],
    whatifs: &[WhatIfResult],
    top_paths: &[&CritPath],
    config: Json,
    obs_dropped: Option<(u64, u64, u64)>,
) -> Json {
    let n = paths.len().max(1) as f64;
    let mean_cov = paths.iter().map(|p| p.coverage).sum::<f64>() / n;
    let min_cov = paths.iter().map(|p| p.coverage).fold(f64::INFINITY, f64::min);
    let bottleneck_rows: Vec<Json> = bottleneck
        .iter()
        .map(|r| {
            jobj(vec![
                ("resource", Json::Str(r.resource.name().to_string())),
                ("total_s", Json::Num(r.total_s)),
                ("share", Json::Num(r.share)),
                ("tail_s", Json::Num(r.tail_s)),
                ("tail_share", Json::Num(r.tail_share)),
            ])
        })
        .collect();
    let phase_rows: Vec<Json> = phases
        .iter()
        .map(|r| {
            jobj(vec![
                ("phase", Json::Str(r.phase.to_string())),
                ("resource", Json::Str(r.resource.name().to_string())),
                ("total_s", Json::Num(r.total_s)),
                ("share", Json::Num(r.share)),
            ])
        })
        .collect();
    let window_rows: Vec<Json> = windows
        .iter()
        .map(|w| {
            jobj(vec![
                ("start_s", Json::Num(w.start_s)),
                ("completions", Json::Num(w.completions as f64)),
                ("totals", resource_totals_json(&w.totals)),
            ])
        })
        .collect();
    let whatif_rows: Vec<Json> = whatifs
        .iter()
        .map(|w| {
            jobj(vec![
                ("name", Json::Str(w.name.to_string())),
                ("desc", Json::Str(w.desc.to_string())),
                ("base_ttft_p99_s", Json::Num(w.base_ttft_p99_s)),
                ("est_ttft_p99_s", Json::Num(w.est_ttft_p99_s)),
                ("delta_ttft_p99_s", Json::Num(w.delta_ttft_p99_s)),
                ("base_e2e_p99_s", Json::Num(w.base_e2e_p99_s)),
                ("est_e2e_p99_s", Json::Num(w.est_e2e_p99_s)),
                ("delta_e2e_p99_s", Json::Num(w.delta_e2e_p99_s)),
                ("base_e2e_mean_s", Json::Num(w.base_e2e_mean_s)),
                ("est_e2e_mean_s", Json::Num(w.est_e2e_mean_s)),
                ("delta_e2e_mean_s", Json::Num(w.delta_e2e_mean_s)),
            ])
        })
        .collect();
    jobj(vec![
        ("schema", Json::Str("halo.critpath.v1".to_string())),
        ("config", config),
        ("requests", Json::Num(paths.len() as f64)),
        ("reconcile_mismatches", Json::Num(mismatches as f64)),
        ("coverage_mean", Json::Num(mean_cov)),
        ("coverage_min", Json::Num(if min_cov.is_finite() { min_cov } else { 0.0 })),
        ("obs_dropped", dropped_json(obs_dropped)),
        ("bottleneck", Json::Arr(bottleneck_rows)),
        ("phases", Json::Arr(phase_rows)),
        ("windows", Json::Arr(window_rows)),
        ("whatif", Json::Arr(whatif_rows)),
        ("top_paths", Json::Arr(top_paths.iter().map(|p| path_json(p)).collect())),
    ])
}

/// A [`Metrics`] record as a flat JSON object (keys match the
/// [`crate::dse::Objective`] vocabulary where one exists).
pub fn metrics_json(m: &Metrics) -> Json {
    jobj(vec![
        ("ttft_p50_s", Json::Num(m.ttft_p50)),
        ("ttft_p99_s", Json::Num(m.ttft_p99)),
        ("e2e_p50_s", Json::Num(m.e2e_p50)),
        ("e2e_p99_s", Json::Num(m.e2e_p99)),
        ("throughput_rps", Json::Num(m.throughput_rps)),
        ("decode_tok_per_s", Json::Num(m.decode_tok_per_s)),
        ("utilization", Json::Num(m.utilization)),
        ("evictions", Json::Num(m.evictions)),
        ("recompute_tokens", Json::Num(m.recompute_tokens)),
        ("kv_transfer_gb", Json::Num(m.kv_transfer_gb)),
        ("worst_tenant_ttft_p99_s", Json::Num(m.worst_tenant_ttft_p99)),
        ("slo_ttft_s", Json::Num(m.slo_ttft)),
        ("slo_attainment", Json::Num(m.slo_attainment)),
        ("cost", Json::Num(m.cost)),
        ("energy_per_token_j", Json::Num(m.energy_per_token_j)),
        ("total_energy_j", Json::Num(m.total_energy_j)),
        ("peak_power_w", Json::Num(m.peak_power_w)),
        ("edp", Json::Num(m.edp)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::router::LeastLoaded;
    use crate::cluster::{FleetBuilder, Interconnect};
    use crate::config::HwConfig;
    use crate::model::LlmConfig;
    use crate::sim::queueing::poisson_trace;

    #[test]
    fn cluster_snapshot_is_tagged_and_self_contained() {
        let llm = LlmConfig::llama2_7b();
        let hw = HwConfig::paper();
        let mut fleet = FleetBuilder::new(&llm, &hw)
            .devices(2)
            .slots(4)
            .interconnect(Interconnect::pcie5())
            .build();
        let trace = poisson_trace(7, 20, 10.0, (64, 512), 16);
        let r = fleet.replay(&trace, &mut LeastLoaded);
        let prof = SelfProfile::new();
        let cfg = jobj(vec![("devices", Json::Num(2.0))]);
        let j = cluster_snapshot(
            &r,
            fleet.cost_walks(),
            fleet.cost_memo_hits(),
            &prof,
            cfg,
            Some((0, 0, 0)),
        );
        assert_eq!(j.path(&["schema"]).and_then(Json::as_str), Some("halo.cluster.v1"));
        assert_eq!(j.path(&["config", "devices"]).and_then(Json::as_f64), Some(2.0));
        assert_eq!(j.path(&["per_device"]).and_then(Json::as_arr).map(<[Json]>::len), Some(2));
        let served = j.path(&["metrics", "counters", "requests_served"]).and_then(Json::as_f64);
        assert_eq!(served, Some(r.requests as f64));
        // drop counters surface per satellite: instrumented-and-lossless
        assert_eq!(j.path(&["obs_dropped", "spans"]).and_then(Json::as_f64), Some(0.0));
        // snapshots must round-trip through the serializer
        let text = j.to_string();
        assert_eq!(Json::parse(&text).unwrap(), j);
    }

    #[test]
    fn critpath_snapshot_is_tagged_and_round_trips() {
        use super::super::critpath::{
            bottleneck_profile, extract_paths, phase_profile, reconcile_paths, windowed_profile,
        };
        use super::super::span::{Recorder, Span, SpanKind};
        use super::super::whatif::{evaluate_all, standard_whatifs};
        use crate::sim::queueing::ServedRequest;
        let served = vec![ServedRequest {
            arrival: 0.0,
            ttft: 0.5,
            e2e: 1.0,
            tenant: 0,
            session: 0,
            tokens: 4,
        }];
        let mut rec = Recorder::new();
        rec.spans.push(Span { kind: SpanKind::Prefill, start: 0.1, dur: 0.4, arrival: 0.0, batch: 1 });
        rec.decode_batch(0.5, 0.5, vec![0.0]);
        let paths = extract_paths(&served, &[&rec], &[]);
        let j = critpath_snapshot(
            &paths,
            reconcile_paths(&paths),
            &bottleneck_profile(&paths, 99.0),
            &phase_profile(&paths),
            &windowed_profile(&paths, 0.5, 2),
            &evaluate_all(&paths, &standard_whatifs()),
            &[&paths[0]],
            jobj(vec![("workload", Json::Str("unit".to_string()))]),
            Some((0, 0, 0)),
        );
        assert_eq!(j.path(&["schema"]).and_then(Json::as_str), Some("halo.critpath.v1"));
        assert_eq!(j.path(&["requests"]).and_then(Json::as_f64), Some(1.0));
        assert_eq!(j.path(&["reconcile_mismatches"]).and_then(Json::as_f64), Some(0.0));
        assert_eq!(j.path(&["whatif"]).and_then(Json::as_arr).map(<[Json]>::len), Some(4));
        assert_eq!(j.path(&["top_paths"]).and_then(Json::as_arr).map(<[Json]>::len), Some(1));
        let text = j.to_string();
        assert_eq!(Json::parse(&text).unwrap(), j);
    }
}

//! Inter-device interconnect model.
//!
//! When a request prefills on one device and decodes on another, its KV
//! cache must cross the fleet interconnect. The model is a simple
//! latency + size/bandwidth pipe — enough to expose the regime change the
//! integration tests assert: phase-disaggregated routing wins when the
//! link is fast relative to decode-step times and loses when KV transfers
//! dominate end-to-end latency.

use crate::model::LlmConfig;

/// A fleet interconnect: per-transfer latency plus a bandwidth pipe, with
/// a per-byte transfer energy so KV handoffs cost joules as well as time.
#[derive(Debug, Clone, PartialEq)]
pub struct Interconnect {
    pub name: &'static str,
    /// Link bandwidth, B/s.
    pub bw: f64,
    /// Per-transfer latency, s (protocol + switch traversal).
    pub latency: f64,
    /// Transfer energy, J/byte (SerDes + wire, both endpoints).
    pub e_per_byte: f64,
}

impl Interconnect {
    pub fn new(bw: f64, latency: f64) -> Self {
        assert!(bw > 0.0 && latency >= 0.0);
        // default transfer energy: board-class SerDes
        Interconnect { name: "custom", bw, latency, e_per_byte: 10.0e-12 }
    }

    /// Override the per-byte transfer energy.
    pub fn with_transfer_energy(mut self, e_per_byte: f64) -> Self {
        assert!(e_per_byte >= 0.0);
        self.e_per_byte = e_per_byte;
        self
    }

    /// The same link with its bandwidth scaled by `k` (latency and
    /// per-byte energy untouched) — the replay-side ground truth for
    /// the critical-path plane's "interconnect bandwidth ×k" what-if.
    pub fn with_bandwidth_scale(mut self, k: f64) -> Self {
        assert!(k > 0.0);
        self.name = "scaled";
        self.bw *= k;
        self
    }

    /// On-board / 2.5D-class link (NVLink-generation bandwidth;
    /// ~1.3 pJ/bit short-reach SerDes).
    pub fn board() -> Self {
        Interconnect { name: "board", bw: 256.0e9, latency: 2.0e-6, e_per_byte: 10.0e-12 }
    }

    /// PCIe Gen5 x16-class link (~4 pJ/bit).
    pub fn pcie5() -> Self {
        Interconnect { name: "pcie5", bw: 64.0e9, latency: 5.0e-6, e_per_byte: 32.0e-12 }
    }

    /// 100 GbE-class link (~20 pJ/bit incl. NIC/switch traversal).
    pub fn ethernet() -> Self {
        Interconnect { name: "eth100g", bw: 12.5e9, latency: 50.0e-6, e_per_byte: 160.0e-12 }
    }

    /// Deliberately slow wide-area-class link (KV transfer dominates; the
    /// per-byte energy covers the long-haul transport chain).
    pub fn wan() -> Self {
        Interconnect { name: "wan", bw: 1.0e9, latency: 1.0e-3, e_per_byte: 20.0e-9 }
    }

    pub fn by_name(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "board" | "nvlink" | "fast" => Some(Self::board()),
            "pcie" | "pcie5" => Some(Self::pcie5()),
            "eth" | "eth100g" | "ethernet" => Some(Self::ethernet()),
            "wan" | "slow" => Some(Self::wan()),
            _ => None,
        }
    }

    /// Wall-clock time to move `bytes` across the link.
    pub fn transfer_time(&self, bytes: u64) -> f64 {
        self.latency + bytes as f64 / self.bw
    }

    /// Energy to move `bytes` across the link, J.
    pub fn transfer_energy(&self, bytes: u64) -> f64 {
        bytes as f64 * self.e_per_byte
    }
}

/// KV-cache bytes for `ctx` tokens of context:
/// `2 (K and V) x layers x ctx x kv_heads x head_dim x kv_bytes`.
pub fn kv_transfer_bytes(llm: &LlmConfig, ctx: usize) -> u64 {
    llm.kv_bytes_per_token() * ctx as u64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transfer_time_is_latency_plus_pipe() {
        let l = Interconnect::new(1.0e9, 1.0e-3);
        assert!((l.transfer_time(1_000_000) - (1.0e-3 + 1.0e-3)).abs() < 1e-12);
        assert!(l.transfer_time(0) == 1.0e-3);
    }

    #[test]
    fn presets_order_by_speed() {
        let bytes = kv_transfer_bytes(&LlmConfig::llama2_7b(), 2048);
        // llama2-7b: 256 KiB/token -> 512 MiB at 2048 ctx
        assert_eq!(bytes, 2048 * 2 * 32 * 4096);
        let t_board = Interconnect::board().transfer_time(bytes);
        let t_pcie = Interconnect::pcie5().transfer_time(bytes);
        let t_eth = Interconnect::ethernet().transfer_time(bytes);
        let t_wan = Interconnect::wan().transfer_time(bytes);
        assert!(t_board < t_pcie && t_pcie < t_eth && t_eth < t_wan);
        // the fast link moves a long-context KV cache in milliseconds,
        // the slow one takes the better part of a second
        assert!(t_board < 5e-3, "{t_board}");
        assert!(t_wan > 0.4, "{t_wan}");
    }

    #[test]
    fn gqa_shrinks_transfers() {
        let llama = kv_transfer_bytes(&LlmConfig::llama2_7b(), 1024);
        let qwen = kv_transfer_bytes(&LlmConfig::qwen3_8b(), 1024);
        assert!(qwen < llama);
    }

    #[test]
    fn transfer_energy_scales_with_bytes_and_link_class() {
        let bytes = kv_transfer_bytes(&LlmConfig::llama2_7b(), 1024);
        let e_board = Interconnect::board().transfer_energy(bytes);
        let e_eth = Interconnect::ethernet().transfer_energy(bytes);
        assert!(e_board > 0.0 && e_eth > e_board);
        assert_eq!(Interconnect::board().transfer_energy(0), 0.0);
        // 2x the bytes, 2x the joules
        assert!((Interconnect::board().transfer_energy(2 * bytes) / e_board - 2.0).abs() < 1e-12);
        // override hook
        let custom = Interconnect::new(1e9, 0.0).with_transfer_energy(5e-12);
        assert!((custom.transfer_energy(1000) - 5e-9).abs() < 1e-20);
    }

    #[test]
    fn bandwidth_scale_shrinks_only_the_pipe_term() {
        let base = Interconnect::ethernet();
        let fast = Interconnect::ethernet().with_bandwidth_scale(2.0);
        assert_eq!(fast.latency, base.latency);
        assert_eq!(fast.e_per_byte, base.e_per_byte);
        let bytes = 1_000_000_000u64;
        let pipe_base = base.transfer_time(bytes) - base.latency;
        let pipe_fast = fast.transfer_time(bytes) - fast.latency;
        assert!((pipe_fast * 2.0 - pipe_base).abs() < 1e-9 * pipe_base);
    }

    #[test]
    fn by_name_roundtrip() {
        for l in [
            Interconnect::board(),
            Interconnect::pcie5(),
            Interconnect::ethernet(),
            Interconnect::wan(),
        ] {
            assert_eq!(Interconnect::by_name(l.name), Some(l.clone()));
        }
        assert!(Interconnect::by_name("carrier-pigeon").is_none());
    }
}

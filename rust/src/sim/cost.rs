//! Joint latency/energy cost oracle: one memoized `simulate_graph` walk
//! per distinct evaluation point serves both planes.
//!
//! The serving simulator used to keep two parallel analytical planes — a
//! latency `CostModel` here in `sim` and an energy `EnergyModel` in
//! `power::model` — each walking `simulate_graph` for every distinct
//! (prefill-length / decode-batch) point, held consistent only by a
//! cross-plane agreement test. HALO's phase-aware mapping argument rests
//! on latency *and* energy moving together per op (CiM's high-throughput
//! prefill vs CiD's low-data-movement decode), so both quantities now
//! come out of a single walk as one [`PhaseCost`]: the latency that
//! advances a device clock and the [`EnergyBreakdown`] charged for the
//! same busy event agree by construction, and a power-tracked replay
//! performs exactly as many graph walks as a latency-only replay (pinned
//! by the walk counters below and `tests/power_plane.rs`).
//!
//! The static floor (HBM refresh + leakage), the thermal/TDP machinery,
//! and the DVFS ladder stay in [`crate::power`]: they are properties of
//! wall-clock time and package state, not of a graph walk.

use std::collections::BTreeMap;

use super::{simulate_graph, EngineSet, PhaseResult};
use crate::config::HwConfig;
use crate::mapping::MappingKind;
use crate::model::{build_decode_graph, build_prefill_graph, LlmConfig, OpGraph};

/// Energy of one simulated event (or an accumulated total), decomposed
/// into the components the arch plane's [`crate::arch::OpCost`] tracks
/// plus the two plane-level terms (link transfers, static floor).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct EnergyBreakdown {
    /// DRAM bank/IO activity: CiD weight streaming, HBM reads feeding the
    /// CiM/SA fill pipelines, logic-die activation streaming.
    pub e_dram: f64,
    /// Compute: in-DRAM MACs, ADC conversions + analog array, systolic
    /// MACs, vector/exponent ops.
    pub e_compute: f64,
    /// On-chip buffers and NoC (bank SRAM, GB/IB/WB/OB, accumulators).
    pub e_buffer: f64,
    /// Weight programming: crossbar cell writes (and SA loads).
    pub e_write: f64,
    /// Interposer / fleet-interconnect bytes (KV handoffs).
    pub e_link: f64,
    /// Static floor integrated over time: HBM refresh + leakage.
    pub e_static: f64,
}

impl EnergyBreakdown {
    pub fn total(&self) -> f64 {
        self.e_dram + self.e_compute + self.e_buffer + self.e_write + self.e_link + self.e_static
    }

    /// Dynamic (activity-proportional) share: everything but the static
    /// floor and link transfers — what the arch plane's per-op costs sum.
    pub fn dynamic(&self) -> f64 {
        self.e_dram + self.e_compute + self.e_buffer + self.e_write
    }

    pub fn add(&mut self, o: &EnergyBreakdown) {
        self.e_dram += o.e_dram;
        self.e_compute += o.e_compute;
        self.e_buffer += o.e_buffer;
        self.e_write += o.e_write;
        self.e_link += o.e_link;
        self.e_static += o.e_static;
    }

    /// `ca * a + cb * b`, componentwise (affine interpolation helper).
    pub fn combine(a: &EnergyBreakdown, ca: f64, b: &EnergyBreakdown, cb: f64) -> EnergyBreakdown {
        EnergyBreakdown {
            e_dram: ca * a.e_dram + cb * b.e_dram,
            e_compute: ca * a.e_compute + cb * b.e_compute,
            e_buffer: ca * a.e_buffer + cb * b.e_buffer,
            e_write: ca * a.e_write + cb * b.e_write,
            e_link: ca * a.e_link + cb * b.e_link,
            e_static: ca * a.e_static + cb * b.e_static,
        }
    }

    /// The dynamic components scaled by `k` (a DVFS voltage square);
    /// link bytes and the static floor are charged elsewhere and pass
    /// through untouched.
    pub fn scaled_dynamic(&self, k: f64) -> EnergyBreakdown {
        EnergyBreakdown {
            e_dram: k * self.e_dram,
            e_compute: k * self.e_compute,
            e_buffer: k * self.e_buffer,
            e_write: k * self.e_write,
            e_link: self.e_link,
            e_static: self.e_static,
        }
    }

    pub fn from_phase(r: &PhaseResult) -> EnergyBreakdown {
        EnergyBreakdown {
            e_dram: r.total.e_dram,
            e_compute: r.total.e_compute,
            e_buffer: r.total.e_buffer,
            e_write: r.total.e_write,
            e_link: 0.0,
            e_static: 0.0,
        }
    }
}

/// Joint cost of one simulated phase event — a prefill, a prefill chunk,
/// or one batched decode step: the latency that advances the device
/// clock and the dynamic energy charged for that same event, both read
/// off a single `simulate_graph` walk.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct PhaseCost {
    pub latency: f64,
    pub energy: EnergyBreakdown,
}

impl PhaseCost {
    pub fn from_phase(r: &PhaseResult) -> PhaseCost {
        PhaseCost { latency: r.latency, energy: EnergyBreakdown::from_phase(r) }
    }

    /// `ca * a + cb * b` on latency and every energy component alike.
    pub fn combine(a: &PhaseCost, ca: f64, b: &PhaseCost, cb: f64) -> PhaseCost {
        PhaseCost {
            latency: ca * a.latency + cb * b.latency,
            energy: EnergyBreakdown::combine(&a.energy, ca, &b.energy, cb),
        }
    }
}

/// Memoized joint analytical cost curves for one (model, hardware,
/// mapping) triple: prefill [`PhaseCost`] per distinct prompt length, and
/// decode-step cost as an affine function of context per batch size
/// (both latency and every energy component are affine in context, so
/// two samples per batch size suffice). Each distinct point walks
/// `simulate_graph` exactly once — whether or not anyone reads the
/// energy half ([`CostModel::walks`] counts the walks).
pub struct CostModel {
    llm: LlmConfig,
    mapping: MappingKind,
    engines: EngineSet,
    prefill_cache: BTreeMap<usize, PhaseCost>,
    dec_coef: BTreeMap<usize, (PhaseCost, PhaseCost)>,
    walks: u64,
    hits: u64,
}

impl CostModel {
    pub fn new(llm: &LlmConfig, hw: &HwConfig, mapping: MappingKind) -> Self {
        CostModel {
            llm: llm.clone(),
            mapping,
            engines: EngineSet::new(hw, mapping),
            prefill_cache: BTreeMap::new(),
            dec_coef: BTreeMap::new(),
            walks: 0,
            hits: 0,
        }
    }

    /// `simulate_graph` walks this model has performed (memo misses
    /// only) — the one-walk-per-point guarantee's observable.
    pub fn walks(&self) -> u64 {
        self.walks
    }

    /// Lookups answered from the memo tables without a walk — with
    /// [`CostModel::walks`], the hit-rate half of the memoization story.
    pub fn memo_hits(&self) -> u64 {
        self.hits
    }

    fn walk(&mut self, graph: &OpGraph) -> PhaseCost {
        self.walks += 1;
        PhaseCost::from_phase(&simulate_graph(graph, &self.engines, self.mapping))
    }

    /// Joint prefill cost for a prompt of `l_in` tokens (batch 1).
    pub fn prefill(&mut self, l_in: usize) -> PhaseCost {
        if let Some(&c) = self.prefill_cache.get(&l_in) {
            self.hits += 1;
            return c;
        }
        let graph = build_prefill_graph(&self.llm, l_in, 1);
        let c = self.walk(&graph);
        self.prefill_cache.insert(l_in, c);
        c
    }

    /// Chunked-prefill cost: prefilling `chunk` new prompt tokens when
    /// `offset` tokens of the prompt are already cached.
    ///
    /// Distinct from `prefill(chunk)`: the chunk's attention attends over
    /// all `offset + chunk` cached tokens. Modeled as the larger of two
    /// lower bounds, both read off the memoized monolithic curve:
    ///
    /// * the *incremental* cost `prefill(offset + chunk) - prefill(offset)`
    ///   (the attention/FFN work the extended prefix adds), and
    /// * the *fresh-pass* cost `prefill(chunk)` (a chunk is still a full
    ///   forward pass — per-pass overheads such as weight streaming do not
    ///   shrink with the cached prefix).
    ///
    /// The max makes a chunked prefill sum to at least the monolithic
    /// `prefill(total)` (the incremental terms telescope), so chunking
    /// trades aggregate prefill throughput for interleaving. Latency and
    /// energy take the max independently (latency by latency, energy by
    /// total joules), preserving both curves' telescoping bound even in
    /// the rare regime where the two bounds disagree on the winner.
    pub fn prefill_chunk(&mut self, offset: usize, chunk: usize) -> PhaseCost {
        assert!(chunk > 0, "empty prefill chunk");
        if offset == 0 {
            return self.prefill(chunk);
        }
        let whole = self.prefill(offset + chunk);
        let prefix = self.prefill(offset);
        let fresh = self.prefill(chunk);
        let inc_latency = (whole.latency - prefix.latency).max(0.0);
        let inc_energy = EnergyBreakdown::combine(&whole.energy, 1.0, &prefix.energy, -1.0);
        PhaseCost {
            latency: inc_latency.max(fresh.latency),
            energy: if inc_energy.total() >= fresh.energy.total() {
                inc_energy
            } else {
                fresh.energy
            },
        }
    }

    /// Joint batched decode-step cost at (batch, context): affine in ctx
    /// — sample two points per batch size and interpolate componentwise.
    pub fn decode_step(&mut self, batch: usize, ctx: usize) -> PhaseCost {
        if let Some(&(base, slope)) = self.dec_coef.get(&batch) {
            self.hits += 1;
            return PhaseCost::combine(&base, 1.0, &slope, ctx.max(1) as f64);
        }
        let g1 = build_decode_graph(&self.llm, 512, batch);
        let c1 = self.walk(&g1);
        let g2 = build_decode_graph(&self.llm, 1024, batch);
        let c2 = self.walk(&g2);
        let slope = PhaseCost::combine(&c2, 1.0 / 512.0, &c1, -1.0 / 512.0);
        let base = PhaseCost::combine(&c1, 1.0, &slope, -512.0);
        self.dec_coef.insert(batch, (base, slope));
        PhaseCost::combine(&base, 1.0, &slope, ctx.max(1) as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::Phase;
    use crate::sim::simulate_phase;

    fn model(mapping: MappingKind) -> CostModel {
        CostModel::new(&LlmConfig::llama2_7b(), &HwConfig::paper(), mapping)
    }

    #[test]
    fn prefill_matches_direct_simulation_on_both_axes() {
        let mut cm = model(MappingKind::Halo1);
        let direct = simulate_phase(
            &LlmConfig::llama2_7b(),
            &HwConfig::paper(),
            MappingKind::Halo1,
            Phase::Prefill,
            777,
            1,
        );
        let c = cm.prefill(777);
        assert_eq!(c.latency, direct.latency);
        assert_eq!(c.energy.dynamic(), direct.energy);
        assert_eq!(c.energy.e_link, 0.0);
        assert_eq!(c.energy.e_static, 0.0);
    }

    #[test]
    fn decode_interpolation_exact_at_sampled_points() {
        let mut cm = model(MappingKind::Halo1);
        let direct = simulate_phase(
            &LlmConfig::llama2_7b(),
            &HwConfig::paper(),
            MappingKind::Halo1,
            Phase::Decode,
            512,
            3,
        );
        let c = cm.decode_step(3, 512);
        assert!(
            (c.latency - direct.latency).abs() < 1e-15 * direct.latency.max(1.0),
            "{} vs {}",
            c.latency,
            direct.latency
        );
        assert!(
            (c.energy.dynamic() / direct.energy - 1.0).abs() < 1e-12,
            "{} vs {}",
            c.energy.dynamic(),
            direct.energy
        );
    }

    #[test]
    fn one_walk_per_distinct_point() {
        let mut cm = model(MappingKind::Halo1);
        assert_eq!(cm.walks(), 0);
        assert_eq!(cm.memo_hits(), 0);
        cm.prefill(512);
        assert_eq!(cm.walks(), 1);
        cm.prefill(512);
        assert_eq!(cm.walks(), 1, "memo hit must not re-walk");
        assert_eq!(cm.memo_hits(), 1);
        // a decode batch samples its two affine points once...
        cm.decode_step(4, 777);
        assert_eq!(cm.walks(), 3);
        cm.decode_step(4, 9000);
        assert_eq!(cm.walks(), 3, "any context interpolates for free");
        assert_eq!(cm.memo_hits(), 2);
        // ...and chunk costs reuse the prefill memo
        cm.prefill_chunk(512, 256);
        assert_eq!(cm.walks(), 5, "prefill(768) + prefill(256); prefill(512) cached");
        cm.prefill_chunk(512, 256);
        assert_eq!(cm.walks(), 5);
    }

    #[test]
    fn chunked_prefill_covers_monolithic_on_both_axes() {
        let mut cm = model(MappingKind::Halo1);
        let total = 2048usize;
        for chunk in [256usize, 512, 1024] {
            let mut lat = 0.0;
            let mut dyn_e = 0.0;
            let mut off = 0;
            while off < total {
                let take = chunk.min(total - off);
                let c = cm.prefill_chunk(off, take);
                lat += c.latency;
                dyn_e += c.energy.dynamic();
                off += take;
            }
            let mono = cm.prefill(total);
            assert!(lat >= mono.latency * (1.0 - 1e-12), "chunk {chunk}: {lat}");
            assert!(lat <= mono.latency * 8.0, "chunk {chunk}: {lat}");
            let mono_e = mono.energy.dynamic();
            assert!(dyn_e >= mono_e * (1.0 - 1e-9), "chunk {chunk}: {dyn_e} < {mono_e}");
            assert!(dyn_e <= mono_e * 8.0, "chunk {chunk}: {dyn_e} vs {mono_e}");
        }
        // later chunks cost at least as much as a fresh pass of their size
        let fresh = cm.prefill(256);
        let late = cm.prefill_chunk(4096, 256);
        assert!(late.latency >= fresh.latency);
        assert!(late.energy.total() >= fresh.energy.total());
    }

    #[test]
    fn energy_monotone_in_tokens_context_and_batch() {
        let mut cm = model(MappingKind::Halo1);
        assert!(cm.prefill(256).energy.dynamic() < cm.prefill(512).energy.dynamic());
        assert!(cm.prefill(512).energy.dynamic() < cm.prefill(2048).energy.dynamic());
        assert!(cm.decode_step(1, 512).energy.dynamic() <= cm.decode_step(1, 2048).energy.dynamic());
        assert!(cm.decode_step(1, 512).energy.dynamic() < cm.decode_step(8, 512).energy.dynamic());
    }

    #[test]
    fn halo_prefill_cheaper_than_cid_decode_cheaper_than_cim() {
        // the §V-B energy asymmetry seen through the joint model
        let mut cid = model(MappingKind::FullCid);
        let mut cim = model(MappingKind::FullCim);
        assert!(cim.prefill(2048).energy.dynamic() < cid.prefill(2048).energy.dynamic());
        assert!(
            cid.decode_step(1, 2048).energy.dynamic() < cim.decode_step(1, 2048).energy.dynamic()
        );
        // and latency moves the same way (the joint struct's raison d'etre)
        assert!(cim.prefill(2048).latency < cid.prefill(2048).latency);
        assert!(cid.decode_step(1, 2048).latency < cim.decode_step(1, 2048).latency);
    }

    #[test]
    fn combine_is_componentwise_affine() {
        let a = EnergyBreakdown { e_dram: 1.0, e_compute: 2.0, ..Default::default() };
        let b = EnergyBreakdown { e_dram: 3.0, e_static: 4.0, ..Default::default() };
        let c = EnergyBreakdown::combine(&a, 2.0, &b, 0.5);
        assert_eq!(c.e_dram, 3.5);
        assert_eq!(c.e_compute, 4.0);
        assert_eq!(c.e_static, 2.0);
        assert!((c.total() - (3.5 + 4.0 + 2.0)).abs() < 1e-12);
        let pa = PhaseCost { latency: 1.0, energy: a };
        let pb = PhaseCost { latency: 3.0, energy: b };
        let pc = PhaseCost::combine(&pa, 2.0, &pb, 0.5);
        assert_eq!(pc.latency, 3.5);
        assert_eq!(pc.energy.e_dram, 3.5);
    }

    #[test]
    fn scaled_dynamic_touches_only_dynamic_components() {
        let e = EnergyBreakdown {
            e_dram: 1.0,
            e_compute: 2.0,
            e_buffer: 3.0,
            e_write: 4.0,
            e_link: 5.0,
            e_static: 6.0,
        };
        let s = e.scaled_dynamic(0.5);
        assert_eq!(s.dynamic(), 5.0);
        assert_eq!(s.e_link, 5.0);
        assert_eq!(s.e_static, 6.0);
        // unit scale is the exact identity (nominal DVFS stays bit-clean)
        assert_eq!(e.scaled_dynamic(1.0), e);
    }
}

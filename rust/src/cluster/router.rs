//! Pluggable request routing across a fleet.
//!
//! A router decides, at arrival time, which device runs a request's
//! prefill and which runs its decode. Unified policies (round-robin,
//! least-loaded) keep both phases on one device; the phase-disaggregated
//! policy splits them across the prefill and decode pools, incurring a
//! KV-cache transfer over the fleet interconnect.

use super::fleet::{Fleet, FleetBuilder};
use super::interconnect::Interconnect;
use crate::config::HwConfig;
use crate::model::LlmConfig;
use crate::sim::device::SchedConfig;
use crate::sim::queueing::TraceRequest;

/// A routing decision: prefill device and decode device (equal indices
/// mean the whole request stays on one device — no KV transfer).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Route {
    pub prefill: usize,
    pub decode: usize,
}

/// Request-routing policy over a fleet.
pub trait Router {
    fn name(&self) -> &'static str;
    /// Route one arriving request given the current fleet state.
    fn route(&mut self, fleet: &Fleet, req: &TraceRequest) -> Route;
}

/// Blind round-robin over the prefill pool; decode stays local.
#[derive(Debug, Default)]
pub struct RoundRobin {
    next: usize,
}

impl Router for RoundRobin {
    fn name(&self) -> &'static str {
        "roundrobin"
    }
    fn route(&mut self, fleet: &Fleet, _req: &TraceRequest) -> Route {
        let pool = &fleet.prefill_pool;
        let dev = pool[self.next % pool.len()];
        self.next = self.next.wrapping_add(1);
        Route { prefill: dev, decode: dev }
    }
}

/// Join-the-shortest-queue over the prefill pool (queue + active slots);
/// decode stays local.
#[derive(Debug, Default)]
pub struct LeastLoaded;

fn argmin_load(fleet: &Fleet, pool: &[usize]) -> usize {
    *pool
        .iter()
        .min_by_key(|&&d| fleet.devices[d].load())
        .expect("empty pool")
}

impl Router for LeastLoaded {
    fn name(&self) -> &'static str {
        "leastloaded"
    }
    fn route(&mut self, fleet: &Fleet, _req: &TraceRequest) -> Route {
        let dev = argmin_load(fleet, &fleet.prefill_pool);
        Route { prefill: dev, decode: dev }
    }
}

/// Cluster-level analogue of HALO's phase-aware mapping: prefill on the
/// least-loaded device of the (Fully-CiM) prefill pool, decode on the
/// least-loaded device of the (Fully-CiD) decode pool.
#[derive(Debug, Default)]
pub struct PhaseDisaggregated;

impl Router for PhaseDisaggregated {
    fn name(&self) -> &'static str {
        "disaggregated"
    }
    fn route(&mut self, fleet: &Fleet, _req: &TraceRequest) -> Route {
        // decode placement must count assignments still in prefill or KV
        // transfer, or bursts herd onto one decode device
        let decode = *fleet
            .decode_pool
            .iter()
            .min_by_key(|&&d| fleet.decode_load(d))
            .expect("empty decode pool");
        Route { prefill: argmin_load(fleet, &fleet.prefill_pool), decode }
    }
}

/// Capacity-aware phase disaggregation: decode placement skips devices
/// whose projected KV headroom cannot hold the request's lifetime KV
/// (`(l_in + l_out) x bytes/token`), then picks the least-loaded fitting
/// device. When no decode device fits — the whole pool is under
/// pressure — it falls back to the device with the most headroom, and
/// the device-level eviction machinery absorbs the overflow.
///
/// Prefill placement is destination-aware too: while the decode pool has
/// headroom it is plain least-loaded, but once the pool is under pressure
/// (nothing fits this request) the prefill goes to the device with the
/// *smallest outbound handoff backlog* — the device whose queued prefills
/// will flood the decode pool last — so this request's KV arrives after
/// the pool has had the most time to drain, instead of piling onto the
/// device already feeding it fastest.
#[derive(Debug, Default)]
pub struct KvAware;

impl Router for KvAware {
    fn name(&self) -> &'static str {
        "kvaware"
    }
    fn route(&mut self, fleet: &Fleet, req: &TraceRequest) -> Route {
        let need = fleet.kv_estimate(req);
        let fitting = fleet
            .decode_pool
            .iter()
            .filter(|&&d| fleet.decode_kv_headroom(d) >= need)
            .min_by_key(|&&d| fleet.decode_load(d))
            .copied();
        let decode = fitting.unwrap_or_else(|| {
            *fleet
                .decode_pool
                .iter()
                .max_by_key(|&&d| fleet.decode_kv_headroom(d))
                .expect("empty decode pool")
        });
        let prefill = if fitting.is_some() {
            argmin_load(fleet, &fleet.prefill_pool)
        } else {
            *fleet
                .prefill_pool
                .iter()
                .min_by_key(|&&d| (fleet.prefill_handoff_backlog(d), fleet.devices[d].load(), d))
                .expect("empty prefill pool")
        };
        Route { prefill, decode }
    }
}

/// Named (fleet topology, router) policies exposed on the CLI and in the
/// report tables.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Policy {
    /// Monolithic HALO1 devices, blind round-robin routing.
    RoundRobin,
    /// Monolithic HALO1 devices, least-loaded routing (the strongest
    /// non-disaggregated baseline).
    LeastLoaded,
    /// Fully-CiM prefill pool feeding a Fully-CiD decode pool.
    PhaseDisaggregated,
    /// Phase-disaggregated pools with KV-capacity-aware decode placement
    /// (skips decode devices whose budget cannot hold the request).
    KvAware,
}

impl Policy {
    /// Every routing policy, in display order — a `'static` slice source
    /// for property-test generators (see also [`Policy::all`]).
    pub const ALL: [Policy; 4] =
        [Policy::RoundRobin, Policy::LeastLoaded, Policy::PhaseDisaggregated, Policy::KvAware];

    pub fn all() -> [Policy; 4] {
        Self::ALL
    }

    pub fn name(&self) -> &'static str {
        match self {
            Policy::RoundRobin => "roundrobin",
            Policy::LeastLoaded => "leastloaded",
            Policy::PhaseDisaggregated => "disaggregated",
            Policy::KvAware => "kvaware",
        }
    }

    pub fn by_name(s: &str) -> Option<Self> {
        let norm: String =
            s.to_ascii_lowercase().chars().filter(|c| *c != '-' && *c != '_').collect();
        match norm.as_str() {
            "roundrobin" | "rr" => Some(Policy::RoundRobin),
            // `monolithic` = every device runs the HALO1 phase-aware
            // mapping end-to-end; least-loaded is its routing
            "leastloaded" | "ll" | "monolithic" | "mono" => Some(Policy::LeastLoaded),
            "disaggregated" | "disagg" | "phasedisaggregated" | "pd" => {
                Some(Policy::PhaseDisaggregated)
            }
            "kvaware" | "kv" | "capacity" | "capacityaware" => Some(Policy::KvAware),
            _ => None,
        }
    }

    /// The router half of this policy alone — for callers (the `dse`
    /// plane) that build the fleet themselves, e.g. with a heterogeneous
    /// per-device mapping composition.
    pub fn router(&self) -> Box<dyn Router> {
        match self {
            Policy::RoundRobin => Box::new(RoundRobin::default()),
            Policy::LeastLoaded => Box::new(LeastLoaded),
            Policy::PhaseDisaggregated => Box::new(PhaseDisaggregated),
            Policy::KvAware => Box::new(KvAware),
        }
    }

    /// Whether this policy routes over split prefill/decode pools (and so
    /// needs a fleet of at least two devices).
    pub fn is_disaggregated(&self) -> bool {
        matches!(self, Policy::PhaseDisaggregated | Policy::KvAware)
    }

    /// Construct the (fleet, router) pair this policy describes.
    /// `prefill_frac` only applies to the disaggregated topologies.
    pub fn build(
        &self,
        llm: &LlmConfig,
        hw: &HwConfig,
        devices: usize,
        slots: usize,
        prefill_frac: f64,
        link: Interconnect,
    ) -> (Fleet, Box<dyn Router>) {
        self.build_with(llm, hw, devices, slots, prefill_frac, link, SchedConfig::default())
    }

    /// [`Policy::build`] under an explicit per-device scheduling
    /// configuration (chunked prefill, admission policy, KV capacity).
    #[allow(clippy::too_many_arguments)]
    pub fn build_with(
        &self,
        llm: &LlmConfig,
        hw: &HwConfig,
        devices: usize,
        slots: usize,
        prefill_frac: f64,
        link: Interconnect,
        sched: SchedConfig,
    ) -> (Fleet, Box<dyn Router>) {
        let builder = FleetBuilder::new(llm, hw)
            .devices(devices)
            .slots(slots)
            .interconnect(link)
            .sched(sched);
        let fleet = if self.is_disaggregated() {
            builder.disaggregated(prefill_frac).build()
        } else {
            builder.build()
        };
        (fleet, self.router())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fleet(n: usize) -> Fleet {
        FleetBuilder::new(&LlmConfig::llama2_7b(), &HwConfig::paper()).devices(n).slots(4).build()
    }

    fn disagg_fleet() -> Fleet {
        FleetBuilder::new(&LlmConfig::llama2_7b(), &HwConfig::paper())
            .devices(4)
            .slots(4)
            .disaggregated(0.5)
            .build()
    }

    fn req() -> TraceRequest {
        TraceRequest { arrival: 0.0, l_in: 128, l_out: 16, tenant: 0, session: 0 }
    }

    #[test]
    fn round_robin_cycles() {
        let f = fleet(3);
        let mut rr = RoundRobin::default();
        let picks: Vec<usize> = (0..6).map(|_| rr.route(&f, &req()).prefill).collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn least_loaded_avoids_busy_device() {
        let mut f = fleet(2);
        f.devices[0].push(crate::sim::device::DeviceJob::full(&req()));
        let mut ll = LeastLoaded;
        let r = ll.route(&f, &req());
        assert_eq!(r.prefill, 1);
        assert_eq!(r.decode, 1);
    }

    #[test]
    fn disaggregated_splits_pools() {
        let f = disagg_fleet();
        let mut pd = PhaseDisaggregated;
        let r = pd.route(&f, &req());
        assert!(f.prefill_pool.contains(&r.prefill));
        assert!(f.decode_pool.contains(&r.decode));
        assert_ne!(r.prefill, r.decode);
    }

    #[test]
    fn policy_by_name() {
        assert_eq!(Policy::by_name("disaggregated"), Some(Policy::PhaseDisaggregated));
        assert_eq!(Policy::by_name("monolithic"), Some(Policy::LeastLoaded));
        assert_eq!(Policy::by_name("round-robin"), Some(Policy::RoundRobin));
        assert_eq!(Policy::by_name("kv-aware"), Some(Policy::KvAware));
        assert!(Policy::by_name("random").is_none());
        for p in Policy::all() {
            assert_eq!(Policy::by_name(p.name()), Some(p));
        }
    }

    #[test]
    fn kv_aware_skips_full_decode_devices() {
        let mut f = disagg_fleet();
        // decode pool = {2, 3}; device 2 gets a budget too small for the
        // request's lifetime KV, device 3 a comfortable one
        let r = req();
        let need = f.kv_estimate(&r);
        f.set_kv_capacity(2, Some(need / 2));
        f.set_kv_capacity(3, Some(need * 100));
        let mut kv = KvAware;
        let route = kv.route(&f, &r);
        assert_eq!(route.decode, 3, "must skip the full decode device");
        assert!(f.prefill_pool.contains(&route.prefill));
        // when nothing fits, fall back to the most-headroom device
        f.set_kv_capacity(3, Some(need / 4));
        let route = kv.route(&f, &r);
        assert_eq!(route.decode, 2, "largest headroom wins under pressure");
    }

    #[test]
    fn kv_aware_prefill_placement_checks_decode_pool_headroom() {
        use crate::sim::device::DeviceJob;
        let mut f = disagg_fleet();
        // prefill pool = {0, 1}: device 0 carries two small handoff
        // prefills (load 2, small outbound KV); device 1 carries one huge
        // one (load 1, large outbound KV)
        for _ in 0..2 {
            f.devices[0].push(DeviceJob::PrefillOnly {
                arrival: 0.0,
                ready: 0.0,
                l_in: 64,
                l_out: 8,
                decode_dev: 2,
            });
        }
        f.devices[1].push(DeviceJob::PrefillOnly {
            arrival: 0.0,
            ready: 0.0,
            l_in: 8192,
            l_out: 8,
            decode_dev: 3,
        });
        assert!(f.prefill_handoff_backlog(1) > f.prefill_handoff_backlog(0));
        let r = req();
        let need = f.kv_estimate(&r);
        let mut kv = KvAware;
        // decode pool has headroom: plain least-loaded prefill placement
        let route = kv.route(&f, &r);
        assert_eq!(route.prefill, 1, "no pressure -> least-loaded prefill device");
        // decode pool under pressure (nothing fits): steer the prefill to
        // the device with the smallest outbound handoff backlog instead
        f.set_kv_capacity(2, Some(need / 2));
        f.set_kv_capacity(3, Some(need / 2));
        let route = kv.route(&f, &r);
        assert_eq!(route.prefill, 0, "pressure -> smallest handoff backlog wins");
        assert!(f.decode_pool.contains(&route.decode));
    }
}
